"""The benchmark JSON contract: every committed BENCH_*.json (and any
row the harness emits) follows the documented ``repro-bench/v1`` shape,
so cross-PR tooling can track throughput / SLO numbers by key without
re-parsing ``derived`` strings."""
import json
import numbers
import pathlib

import pytest

from benchmarks import run as bench

ROOT = pathlib.Path(__file__).resolve().parent.parent


def assert_valid_row(r):
    assert isinstance(r.get("name"), str) and r["name"]
    assert isinstance(r.get("us_per_call"), numbers.Real)
    assert r["us_per_call"] >= 0
    assert isinstance(r.get("derived"), str)
    extras = set(r) - {"name", "us_per_call", "derived"}
    unknown = extras - bench.KNOWN_EXTRA_KEYS
    assert not unknown, \
        f"row {r['name']!r} carries undocumented extras {sorted(unknown)}; " \
        f"register them in benchmarks.run.KNOWN_EXTRA_KEYS"
    for k in extras:
        assert isinstance(r[k], (numbers.Real, bool)), \
            f"extra {k}={r[k]!r} must be numeric or bool"


def test_row_helper_emits_documented_shape():
    before = list(bench.ROWS)
    try:
        bench.ROWS.clear()
        bench.row("x_probe", 12.34, "detail=1", tok_s=5.0, steps_lost=0)
        (r,) = bench.ROWS
        assert r["name"] == "x_probe" and r["us_per_call"] == 12.3
        assert_valid_row(r)
    finally:
        bench.ROWS[:] = before


def committed_bench_files():
    return sorted(ROOT.glob("BENCH_*.json"))


def test_train_bench_is_committed():
    """ISSUE 7 acceptance: BENCH_train.json carries the per-step vs
    chunked-dispatch trajectory, with chunked host syncs/step reduced."""
    path = ROOT / "BENCH_train.json"
    assert path.exists(), "BENCH_train.json must be committed"
    doc = json.loads(path.read_text())
    rows = {r["name"]: r for r in doc["rows"]}
    per_step = rows["train_per_step"]
    chunked = next(v for k, v in rows.items()
                   if k.startswith("train_chunked_k"))
    for r in (per_step, chunked):
        assert {"tok_s", "host_syncs_per_step", "t_first_s",
                "device_steps"} <= set(r)
    assert per_step["device_steps"] == 1
    assert chunked["device_steps"] > 1
    # the point of the hot loop: host round-trips per optimizer step
    # drop from O(1) to O(1/device_steps)
    assert chunked["host_syncs_per_step"] < per_step["host_syncs_per_step"]


def test_scenario_bench_is_committed():
    """ISSUE 6 acceptance: BENCH_scenarios.json exists with >= 1 row."""
    path = ROOT / "BENCH_scenarios.json"
    assert path.exists(), "BENCH_scenarios.json must be committed"
    doc = json.loads(path.read_text())
    names = [r["name"] for r in doc["rows"]]
    assert "scenario_chaos_run" in names
    tenant_rows = [r for r in doc["rows"]
                   if r["name"].startswith("scenario_tenant_")]
    assert tenant_rows, "per-tenant SLO scorecard rows missing"
    for r in tenant_rows:
        assert {"goodput", "slo_pass", "p99_ttft_s", "p99_latency_s",
                "steps_lost", "chargeback_usd"} <= set(r)


def test_serving_bench_is_committed():
    """Serving-at-scale acceptance: BENCH_serving.json pits the static
    drain-then-refill batcher against the autoscaled paged+prefix
    replica fleet, and the fleet wins on BOTH p99 TTFT (measured from
    enqueue) and tok/s (acked completions only), with the prefix hit
    rate and replica scale events recorded in the row."""
    path = ROOT / "BENCH_serving.json"
    assert path.exists(), "BENCH_serving.json must be committed"
    doc = json.loads(path.read_text())
    rows = {r["name"]: r for r in doc["rows"]}
    static = rows["serving_static"]
    fleet = rows["serving_paged_autoscaled"]
    assert {"tok_s", "p99_ttft_s"} <= set(static)
    assert {"tok_s", "p99_ttft_s", "prefix_hit_rate", "scale_events",
            "replicas_max", "stale_tokens"} <= set(fleet)
    assert fleet["tok_s"] > static["tok_s"]
    assert fleet["p99_ttft_s"] < static["p99_ttft_s"]
    assert fleet["prefix_hit_rate"] > 0
    assert fleet["scale_events"] >= 1
    assert fleet["replicas_max"] >= 2


def test_workflow_bench_is_committed():
    """ISSUE 8 acceptance: BENCH_workflow.json shows the concurrent
    fan-out (width >= 8, branches spread over 3 sites) finishing in
    < 0.6x the serial makespan."""
    path = ROOT / "BENCH_workflow.json"
    assert path.exists(), "BENCH_workflow.json must be committed"
    doc = json.loads(path.read_text())
    rows = {r["name"]: r for r in doc["rows"]}
    serial = rows["workflow_fanout_serial"]
    conc = rows["workflow_fanout_concurrent"]
    assert serial["width"] >= 8 and conc["width"] == serial["width"]
    assert conc["branch_sites"] >= 3
    assert conc["makespan_s"] < 0.6 * serial["makespan_s"]
    assert conc["fanout_ratio"] < 0.6


def test_rl_bench_is_committed():
    """ISSUE 10 acceptance: BENCH_rl.json carries the actor-fleet /
    learner co-tenant run with its chaos accounting — rollout tok/s,
    learner steps/s, p99 policy lag inside the staleness bound, and
    steps_lost <= ckpt_every under one actor kill + one learner
    preemption + one injected learner crash."""
    path = ROOT / "BENCH_rl.json"
    assert path.exists(), "BENCH_rl.json must be committed"
    doc = json.loads(path.read_text())
    rows = {r["name"]: r for r in doc["rows"]}
    fleet = rows["rl_rollout_fleet"]
    learner = rows["rl_learner_steps"]
    chaos = rows["rl_chaos_recovery"]
    assert fleet["rollout_tok_s"] > 0 and fleet["trained"] > 0
    assert fleet["bytes_moved"] > 0          # metered federated weight pulls
    assert learner["learner_steps_s"] > 0
    assert learner["weight_syncs"] >= 1
    # the staleness contract: nothing trained-on beyond max_policy_lag=2
    assert learner["max_lag_trained"] <= 2
    assert learner["policy_lag_p99"] <= 2
    # chaos recovery: crash resume bounded by the checkpoint cadence (2),
    # and the killed actor's ticket leases were requeued, not lost
    assert chaos["preemptions"] >= 1 and chaos["crashes"] >= 1
    assert chaos["steps_lost"] <= 2
    assert chaos["requeued_tickets"] >= 1


@pytest.mark.parametrize("path", committed_bench_files(),
                         ids=lambda p: p.name)
def test_committed_bench_json_validates(path):
    doc = json.loads(path.read_text())
    assert doc.get("schema") == bench.JSON_SCHEMA
    assert isinstance(doc.get("created_unix"), numbers.Real)
    assert isinstance(doc.get("fast"), bool)
    rows = doc.get("rows")
    assert isinstance(rows, list) and len(rows) >= 1
    for r in rows:
        assert_valid_row(r)
