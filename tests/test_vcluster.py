"""Multi-tenant virtual clusters: fair-share scheduling, cooperative
preemption (checkpoint-then-evict), capacity claims, tenant-aware
placement, and the near-real-time monitor stream."""
import threading
import time

import pytest

from repro.core.orchestrator import Cluster, JobSpec, PodState
from repro.fabric import Fabric, FederatedStore
from repro.vcluster import (EventBus, FairShareScheduler, TenantSpec,
                            VirtualCluster)


def mk_fabric(devs=(2, 2)):
    fabric = Fabric()
    for i, n in enumerate(devs):
        fabric.add_site(f"s{i}", devices=list(range(n)))
    names = list(fabric.sites)
    for i, a in enumerate(names):
        for b in names[i + 1:]:
            fabric.connect(a, b, gbps=1.0, latency_ms=1.0)
    return fabric


def hold_fn(release: threading.Event, timeout=20.0):
    def fn(ctx):
        end = time.monotonic() + timeout
        while time.monotonic() < end:
            if ctx.should_stop():
                return "stopped"
            if release.is_set():
                return "ok"
            time.sleep(0.005)
        return "timeout"
    return fn


def timed_fn(dur):
    def fn(ctx):
        end = time.monotonic() + dur
        while time.monotonic() < end and not ctx.should_stop():
            time.sleep(0.005)
        return "ok"
    return fn


# --------------------------------------------------------------- tenancy

def test_tenant_namespaces_and_quota():
    fabric = mk_fabric((4, 2))
    sched = FairShareScheduler(fabric)
    vc = sched.create_tenant(TenantSpec("acme", site_quota=2))
    assert isinstance(vc, VirtualCluster)
    for site in fabric.sites.values():
        ns = site.cluster.namespaces["tenant-acme"]
        assert ns.device_quota == 2
    # the orchestrator enforces the per-site quota on direct submissions
    with pytest.raises(RuntimeError, match="quota"):
        fabric.sites["s0"].cluster.submit("tenant-acme", JobSpec(
            "big", lambda ctx: 1, devices_per_pod=3))
    assert vc.usage() == {"s0": 0, "s1": 0}
    assert vc.dominant_share() == 0.0


def test_duplicate_tenant_rejected():
    sched = FairShareScheduler(mk_fabric())
    sched.create_tenant(TenantSpec("a"))
    with pytest.raises(ValueError, match="exists"):
        sched.create_tenant(TenantSpec("a"))
    with pytest.raises(ValueError, match="weight"):
        TenantSpec("bad", weight=0.0)


def test_dominant_share_is_weighted():
    fabric = mk_fabric((4,))
    sched = FairShareScheduler(fabric)
    a = sched.create_tenant(TenantSpec("a", weight=1.0))
    b = sched.create_tenant(TenantSpec("b", weight=2.0))
    release = threading.Event()
    ja = a.submit(JobSpec("ja", hold_fn(release), devices_per_pod=2))
    jb = b.submit(JobSpec("jb", hold_fn(release), devices_per_pod=2))
    try:
        sched.step()
        assert ja.state == jb.state == "running"
        # same devices, but b's weight halves its dominant share
        assert a.dominant_share() == pytest.approx(0.5)
        assert b.dominant_share() == pytest.approx(0.25)
    finally:
        release.set()
        with sched:                 # reap needs the reconcile loop
            ja.wait(20), jb.wait(20)


# --------------------------------------------------- fair-share placement

def test_fair_share_interleaves_equal_tenants():
    """With everything queued up-front, placements alternate tenants
    (dominant share re-ranked after every launch), not arrival order."""
    fabric = mk_fabric((2,))
    sched = FairShareScheduler(fabric)
    a = sched.create_tenant(TenantSpec("a"))
    b = sched.create_tenant(TenantSpec("b"))
    release = threading.Event()
    ja = [a.submit(JobSpec(f"a{i}", hold_fn(release), devices_per_pod=1))
          for i in range(2)]
    jb = [b.submit(JobSpec(f"b{i}", hold_fn(release), devices_per_pod=1))
          for i in range(2)]
    try:
        sched.step()
        # one slot each — NOT both of a's jobs (a submitted first)
        assert ja[0].state == "running" and jb[0].state == "running"
        assert ja[1].state == "queued" and jb[1].state == "queued"
    finally:
        release.set()
        with sched:
            for j in ja + jb:
                j.wait(20)


def test_fifo_policy_is_arrival_order():
    fabric = mk_fabric((2,))
    sched = FairShareScheduler(fabric, policy="fifo")
    a = sched.create_tenant(TenantSpec("a"))
    b = sched.create_tenant(TenantSpec("b"))
    release = threading.Event()
    ja = [a.submit(JobSpec(f"a{i}", hold_fn(release), devices_per_pod=1))
          for i in range(2)]
    jb = b.submit(JobSpec("b0", hold_fn(release), devices_per_pod=1))
    try:
        sched.step()
        assert [j.state for j in ja] == ["running", "running"]
        assert jb.state == "queued"            # head-of-line blocked
    finally:
        release.set()
        with sched:
            for j in ja + [jb]:
                j.wait(20)


def test_fairness_under_contention():
    """Acceptance: equal-share tenants on a saturated 2-site fabric
    finish within 20% of each other; FIFO skews >2x."""
    def run(policy):
        fabric = mk_fabric((2, 2))
        sched = FairShareScheduler(fabric, policy=policy, reconcile_s=0.01)
        tenants = [sched.create_tenant(TenantSpec(n)) for n in ("a", "b")]
        t0 = time.monotonic()
        jobs = [[vc.submit(JobSpec(f"{vc.name}{i}", timed_fn(0.04),
                                   devices_per_pod=1)) for i in range(10)]
                for vc in tenants]
        with sched:
            for js in jobs:
                for j in js:
                    j.wait(60)
        mk = [max(j.done_ts for j in js) - t0 for js in jobs]
        mc = [sum(j.done_ts - t0 for j in js) / len(js) for js in jobs]
        return max(mk) / min(mk), max(mc) / min(mc)

    mk_ratio, _ = run("fair")
    assert mk_ratio <= 1.2, f"fair-share makespan ratio {mk_ratio}"
    _, mc_skew = run("fifo")
    assert mc_skew > 2.0, f"FIFO completion skew only {mc_skew}"


def test_tenant_ceiling_enforced():
    fabric = mk_fabric((4,))
    sched = FairShareScheduler(fabric)
    capped = sched.create_tenant(TenantSpec("capped", max_devices=2))
    release = threading.Event()
    jobs = [capped.submit(JobSpec(f"j{i}", hold_fn(release),
                                  devices_per_pod=1)) for i in range(4)]
    try:
        sched.step()
        running = [j for j in jobs if j.state == "running"]
        assert len(running) == 2            # ceiling, not site capacity
    finally:
        release.set()
        with sched:
            for j in jobs:
                j.wait(20)


# ------------------------------------------------------------- preemption

def test_preempt_pod_cooperative_and_no_respawn():
    cluster = Cluster(devices=list(range(2)))
    cluster.create_namespace("default")
    release = threading.Event()
    job = cluster.submit("default", JobSpec("victim", hold_fn(release),
                                            devices_per_pod=2))
    pod = job.pods[0]
    for _ in range(200):
        if pod.state == PodState.RUNNING:
            break
        time.sleep(0.01)
    assert cluster.preempt_pod(pod, reason="test")
    pod.thread.join(timeout=10)
    assert pod.state == PodState.PREEMPTED
    assert pod.result == "stopped"          # cooperative exit value kept
    assert not cluster.leased               # lease returned
    assert cluster.namespaces["default"].used_devices == 0
    assert cluster.reconcile() == 0         # PREEMPTED is never respawned
    assert not cluster.preempt_pod(pod)     # already terminal


def test_preempt_pending_pod_immediate():
    """A pod preempted while still PENDING is evicted on the spot and its
    fn never runs, even if the controller later tries to start it."""
    from repro.core.orchestrator import Pod, PodCtx
    cluster = Cluster(devices=list(range(1)))
    cluster.create_namespace("default")
    ctx = PodCtx("p0", "default", [], cluster.metrics)
    ran = []
    pod = Pod("p0", lambda c: ran.append(1) or "never", ctx)
    assert cluster.preempt_pod(pod, reason="test")
    assert pod.state == PodState.PREEMPTED
    assert pod.ctx.preempt.is_set()
    cluster._start_pod(pod)             # a stale start is fenced out
    pod.thread.join(timeout=10)
    assert not ran and pod.result is None


def test_finish_preempt_hard_evicts_stuck_pod():
    """A pod that ignores the cooperative drain is force-evicted: lease
    freed, terminal PREEMPTED — and its late result is still recorded."""
    cluster = Cluster(devices=list(range(2)))
    cluster.create_namespace("default")
    release = threading.Event()

    def stubborn(ctx):
        release.wait(10)            # never polls should_stop
        return "late"

    job = cluster.submit("default", JobSpec("stub", stubborn,
                                            devices_per_pod=2))
    pod = job.pods[0]
    for _ in range(200):
        if pod.state == PodState.RUNNING:
            break
        time.sleep(0.01)
    assert cluster.preempt_pod(pod)
    assert not cluster.finish_preempt(pod) or True  # idempotence probed below
    cluster.finish_preempt(pod)
    assert pod.state == PodState.PREEMPTED
    assert not cluster.leased
    release.set()
    pod.thread.join(timeout=10)
    assert pod.state == PodState.PREEMPTED          # not resurrected
    assert pod.result == "late"


def test_scheduler_preempts_lower_priority_and_requeues():
    fabric = mk_fabric((2,))
    sched = FairShareScheduler(fabric, preempt_grace_s=5.0)
    low = sched.create_tenant(TenantSpec("low", priority=0))
    high = sched.create_tenant(TenantSpec("high", priority=10,
                                          preemptible=False))
    sub = sched.bus.subscribe(maxlen=4096)
    jl = low.submit(JobSpec("hold", timed_fn(10.0), replicas=2,
                            devices_per_pod=1))
    sched.step()
    assert jl.state == "running"
    jh = high.submit(JobSpec("burst", timed_fn(0.05), devices_per_pod=2))
    with sched:
        jh.wait(30)
        # the preempted low job is requeued and reruns to completion
        jl.wait(30)
    assert jl.preemptions >= 1
    assert jh.results() == ["ok"]
    evs = [e for e in sub.poll(0) if e.data.get("action") == "preempt"]
    assert evs, "preemption must be published to the monitor"
    sub.close()


def test_higher_priority_never_preempted():
    fabric = mk_fabric((2,))
    sched = FairShareScheduler(fabric)
    high = sched.create_tenant(TenantSpec("high", priority=10))
    low = sched.create_tenant(TenantSpec("low", priority=0))
    release = threading.Event()
    jh = high.submit(JobSpec("hold", hold_fn(release), replicas=2,
                             devices_per_pod=1))
    sched.step()
    jl = low.submit(JobSpec("wish", timed_fn(0.01), devices_per_pod=2))
    try:
        for _ in range(5):
            sched.step()
        assert jl.state == "queued"         # waits, never evicts upward
        assert jh.state == "running"
        assert all(not p.ctx.preempt.is_set() for p in jh.job.pods)
    finally:
        release.set()
        with sched:
            jh.wait(20)
            jl.wait(20)


# ------------------------------------------------------- capacity claims

def test_claim_grant_shrink_and_regrow():
    fabric = mk_fabric((2,))
    sched = FairShareScheduler(fabric, preempt_grace_s=5.0)
    low = sched.create_tenant(TenantSpec("low", priority=0))
    high = sched.create_tenant(TenantSpec("high", priority=10,
                                          preemptible=False))
    claim = low.claim("s0", 2)
    assert claim.granted == 2
    view = low.view("s0", claim)
    assert len(view.online_devices) == 2
    # the claim's segment pod occupies the grant
    seg = view.submit("tenant-low", JobSpec("seg", timed_fn(10.0),
                                            devices_per_pod=2,
                                            backoff_limit=0))
    sub = sched.bus.subscribe(maxlen=4096)
    jh = high.submit(JobSpec("burst", timed_fn(0.05), devices_per_pod=1))
    with sched:
        jh.wait(30)
    # the grant was shrunk to make room and the pod preempt-drained
    # (by now regrow may already have restored it — check the stream)
    assert seg.pods[0].state == PodState.PREEMPTED
    assert fabric.metrics.series("vcluster/preemptions/low").total >= 1
    evs = sub.poll(0)
    assert any(e.data.get("action") == "grant" for e in evs), \
        "the re-grow must be published"
    # after the burst finishes, spare devices re-grow the claim
    for _ in range(10):
        sched.step()
        if claim.granted == 2:
            break
        time.sleep(0.02)
    assert claim.granted == 2
    claim.release()
    assert claim.released and claim not in sched._claims
    sub.close()


def test_claim_floor_blocks_preemption():
    fabric = mk_fabric((2,))
    sched = FairShareScheduler(fabric)
    low = sched.create_tenant(TenantSpec("low", priority=0))
    high = sched.create_tenant(TenantSpec("high", priority=10))
    claim = low.claim("s0", 2, min_devices=2)   # guaranteed floor
    view = low.view("s0", claim)
    seg = view.submit("tenant-low", JobSpec("seg", timed_fn(0.3),
                                            devices_per_pod=2,
                                            backoff_limit=0))
    jh = high.submit(JobSpec("burst", timed_fn(0.01), devices_per_pod=1))
    for _ in range(5):
        sched.step()
    assert claim.granted == 2                   # floor held
    assert seg.pods[0].state == PodState.RUNNING
    assert jh.state == "queued"                 # even a prio-10 job waits
    seg.pods[0].thread.join(timeout=20)
    sched.step()
    # the floor is a standing reservation: still blocked after the
    # segment drains; only releasing the claim frees the devices
    assert jh.state == "queued"
    claim.release()
    with sched:
        jh.wait(30)
    assert jh.state == "done"


# ------------------------------------------------ tenant-aware placement

def test_tenant_planner_bills_and_routes_around_backlog():
    fabric = mk_fabric((2, 2, 2))
    fed = FederatedStore(fabric)
    sched = FairShareScheduler(fed=fed)
    me = sched.create_tenant(TenantSpec("me"))
    other = sched.create_tenant(TenantSpec("other"))
    fed.put("d/x", b"z" * 1_000_000, "s0")
    planner = me.planner()
    assert planner.tenant == "me"
    # symmetric links: without backlog the tie-break picks s1
    base = planner.place(["d/x"], devices=1)
    # "other" saturates s0->s1 with a long in-flight pre-stage: the
    # backlog penalty must steer me's step to s2 instead
    with fabric.reserve("s0", "s1", 500_000_000, tenant="other"):
        p = planner.place(["d/x"], devices=1)
        assert p.site in ("s0", "s2") and p.site != "s1"
        # my OWN backlog must not penalize me
        with fabric.reserve("s0", "s2", 500_000_000, tenant="me"):
            p2 = planner.place(["d/x"], devices=1)
            assert p2.site != "s1"
    # staging through the tenant planner bills the tenant's meter
    planner.prestage(["d/x"], "s2")
    assert fabric.metrics.series(
        "fabric/tenant/me/bytes_moved").total == 1_000_000
    assert fabric.metrics.series(
        "fabric/tenant/other/bytes_moved").total == 0
    assert base.site in ("s0", "s1")


def test_workflow_under_tenant():
    import numpy as np
    fabric = mk_fabric((2, 2))
    fed = FederatedStore(fabric)
    sched = FairShareScheduler(fed=fed)
    vc = sched.create_tenant(TenantSpec("lab"))
    sub = sched.bus.subscribe()
    fed.view("s1").put_array("in/x.npy", np.arange(8).astype(np.float64))
    wf = vc.workflow("w")
    from repro.core.workflow import Step
    wf.add(Step("sum", lambda ctx: {
        "s": float(ctx.store.get_array("in/x.npy").sum())},
        inputs=["in/x.npy"]))
    out = wf.run()
    assert out["sum"]["s"] == 28.0
    assert wf.namespace == "tenant-lab"
    evs = sub.poll(0)
    steps = [e for e in evs if e.kind == "step"]
    assert {e.data["status"] for e in steps} >= {"placed", "done"}
    sub.close()


# ----------------------------------------------------------- monitor bus

def test_event_bus_ordering_and_bounded_lag():
    bus = EventBus()
    sub = bus.subscribe(maxlen=100)
    recv = []
    stop = threading.Event()

    def poller():
        while True:
            got = sub.poll(timeout=0.02)
            recv.extend((e, time.time()) for e in got)
            if not got and stop.is_set():
                return

    th = threading.Thread(target=poller)
    th.start()
    for i in range(50):
        bus.publish("sched", source="t", i=i)
        time.sleep(0.001)
    stop.set()
    th.join(timeout=10)
    assert [e.data["i"] for e, _ in recv] == list(range(50))   # in order
    assert sub.dropped == 0
    max_lag = max(ts - e.ts for e, ts in recv)
    assert max_lag < 0.5, f"event lag {max_lag}s"


def test_event_bus_bounded_overflow_drops_oldest():
    bus = EventBus()
    sub = bus.subscribe(maxlen=4)
    for i in range(10):
        bus.publish("x", i=i)
    got = sub.poll(0)
    assert [e.data["i"] for e in got] == [6, 7, 8, 9]    # newest window
    assert sub.dropped == 6
    sub.close()
    bus.publish("x", i=99)          # closed subscriber is detached
    assert bus.published == 11


def test_event_bus_slow_subscriber_at_scenario_scale():
    """A dashboard that stops polling must not stall the platform: many
    concurrent publishers push scenario-scale traffic past one stuck
    subscriber.  Publishers stay unblocked, the oldest events drop and
    are counted, and ``stats()`` exposes the loss for the report card."""
    from repro.core.metrics import Registry
    reg = Registry()
    bus = EventBus(metrics=reg)
    stuck = bus.subscribe(maxlen=64)         # never polled during the storm
    healthy = bus.subscribe(maxlen=100_000)
    n_threads, per_thread = 4, 2000

    def blast(k):
        for i in range(per_thread):
            bus.publish("sched", source=f"t{k}", i=i)

    threads = [threading.Thread(target=blast, args=(k,))
               for k in range(n_threads)]
    t0 = time.monotonic()
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=30)
    wall = time.monotonic() - t0
    total = n_threads * per_thread
    assert wall < 10.0, f"publishers blocked by a stuck subscriber ({wall}s)"
    assert bus.published == total
    # the stuck subscriber kept only its newest window, loss on record
    assert stuck.dropped == total - 64
    assert len(stuck.poll(0)) == 64
    assert len(healthy.poll(0)) == total and healthy.dropped == 0
    st = bus.stats()
    assert st["published"] == total
    by_len = {s["maxlen"]: s for s in st["subscribers"]}
    assert by_len[64]["dropped"] == total - 64
    assert by_len[64]["queued"] == 0         # drained just above
    assert reg.series("monitor/dropped").total == total - 64
    stuck.close(), healthy.close()


def test_stranded_job_requeues_off_dead_site():
    """Whole-site loss mid-run: a placed job whose site dies must not sit
    failed forever (step() only reconciles UP sites) — the scheduler
    retires the stranded pods and requeues the job onto a survivor."""
    fabric = mk_fabric((1, 1))
    sched = FairShareScheduler(fabric, reconcile_s=0.02)
    vc = sched.create_tenant(TenantSpec("a"))
    tj = vc.submit(JobSpec("j", timed_fn(0.25), devices_per_pod=1,
                           backoff_limit=0))
    sched.step()
    assert tj.state == "running"
    doomed = tj.site
    survivor = ({"s0", "s1"} - {doomed}).pop()
    fabric.fail_site(doomed)
    with sched:
        tj.wait(30)
    assert tj.state == "done"
    assert tj.site == survivor
    assert tj.preemptions == 1               # the requeue was counted
    assert tj.results() == ["ok"]


def test_bus_carries_node_pod_and_transfer_events():
    fabric = mk_fabric((2, 2))
    bus = EventBus()
    bus.attach_fabric(fabric)
    sub = bus.subscribe()
    # pod events
    cluster = fabric.sites["s0"].cluster
    cluster.create_namespace("default")
    job = cluster.submit("default", JobSpec("j", lambda ctx: "ok",
                                            devices_per_pod=1))
    cluster.wait(job, timeout=20)
    # node churn + transfer
    cluster.fail_node(cluster.devices[0])
    cluster.join_node(cluster.devices[0])
    fabric.transfer("s0", "s1", 1000, tenant="t")
    kinds = {e.kind for e in sub.poll(0)}
    assert {"pod", "node", "transfer"} <= kinds


def test_registry_listener_streams_metrics():
    from repro.core.metrics import Registry
    reg = Registry()
    bus = EventBus()
    bus.attach_registry(reg, prefixes=("elastic/",))
    sub = bus.subscribe()
    reg.gauge("elastic/loss", 1.5)
    reg.inc("unrelated/x")
    evs = sub.poll(0)
    assert len(evs) == 1
    assert evs[0].data == {"name": "elastic/loss", "value": 1.5}


# ------------------------------------- preempted training resumes (e2e)

def test_elastic_preempt_resume_under_tenant():
    """Acceptance: a fair-share preemption checkpoint-evicts the training
    segment, the burst runs, the grant returns, and training resumes from
    the checkpoint — steps lost within the elastic ckpt_every bound."""
    import jax
    from repro.configs import registry as arch_registry
    from repro.configs.base import OptimizerConfig
    from repro.elastic.trainer import ElasticTrainSpec

    fabric = Fabric()
    fabric.add_site("gpu", cluster=Cluster(devices=[jax.devices()[0]]))
    sched = FairShareScheduler(fabric, reconcile_s=0.02,
                               preempt_grace_s=60.0)
    train = sched.create_tenant(TenantSpec("train", priority=0))
    burst = sched.create_tenant(TenantSpec("burst", priority=10,
                                           preemptible=False))
    steps = 10
    spec = ElasticTrainSpec(
        arch_registry.get_smoke("phi4-mini-3.8b"),
        arch_registry.get_parallel("phi4-mini-3.8b"),
        OptimizerConfig(warmup_steps=2, decay_steps=100),
        steps=steps, seq_len=32, global_batch=4, base_shape=(1, 1),
        max_data=1, ckpt_every=2, log_every=1, rejoin_timeout_s=120.0,
        verbose=False)

    def fire_burst():
        while fabric.metrics.series("elastic/step").last < 3:
            time.sleep(0.005)
        burst.submit(JobSpec("burst", timed_fn(0.3),
                             devices_per_pod=1)).wait(120)

    th = threading.Thread(target=fire_burst, daemon=True)
    with sched:
        th.start()
        out = train.run_elastic(spec, site="gpu", devices=1)
        th.join(timeout=120)

    rep = out["report"]
    assert "preempted" in [s.outcome for s in rep.segments]
    assert rep.segments[-1].end == steps - 1            # finished
    assert sorted(out["loss_by_step"]) == list(range(steps))
    assert rep.steps_lost <= spec.ckpt_every            # elastic bound
    assert fabric.metrics.series("elastic/preemptions").total >= 1
