"""Elastic training subsystem: global-batch-invariant accumulation, churn
controller decisions, accum-equivalence of the train step, the thin train
launcher (degenerate 1-node cluster, crash auto-resume), and the end-to-end
self-healing churn run (subprocess: needs 8 forced host devices)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import OptimizerConfig, ShapeConfig
from repro.core.elastic import rescale_plan
from repro.core.orchestrator import Cluster
from repro.elastic import ChurnController, batch_plan
from repro.launch.mesh import single_device_mesh

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ------------------------------------------------------------- batch plans

def test_batch_plan_keeps_global_batch_constant():
    per_replica = 16 // 4                      # sized for the (4, x) mesh
    for data in (4, 2, 1):
        bp = batch_plan(16, data, per_replica=per_replica)
        assert bp.microbatch * bp.accum_steps == 16
        assert bp.per_replica == per_replica
    assert batch_plan(16, 4, per_replica=4).accum_steps == 1
    assert batch_plan(16, 2, per_replica=4).accum_steps == 2
    assert batch_plan(16, 1, per_replica=4).accum_steps == 4


def test_batch_plan_no_bound_means_no_accum():
    assert batch_plan(32, 2).accum_steps == 1


def test_batch_plan_never_overshoots_memory_bound():
    """Divisibility snapping must step accumulation UP (smaller
    microbatches), never down past the per-replica budget."""
    bp = batch_plan(20, 1, per_replica=3)
    assert bp.per_replica <= 3 and bp.accum_steps == 10
    for g, d, pr in [(24, 4, 2), (12, 2, 5), (16, 1, 3)]:
        bp = batch_plan(g, d, per_replica=pr)
        assert bp.per_replica <= pr, (g, d, pr, bp)
        assert bp.microbatch * bp.accum_steps == g


def test_batch_plan_rejects_indivisible():
    with pytest.raises(ValueError, match="not divisible"):
        batch_plan(10, 4)


def test_rescale_plan_max_data_cap():
    plan = rescale_plan(("data", "model"), (1, 1), 8, max_data=1)
    assert plan.new_shape == (1, 1)
    plan = rescale_plan(("data", "model"), (4, 2), 8, max_data=2)
    assert plan.new_shape == (2, 2)


# -------------------------------------------------------------- controller

def test_controller_decides_shrink_and_grow():
    cluster = Cluster(devices=list(range(8)))
    ctl = ChurnController(cluster, axes=("data", "model"),
                          base_shape=(4, 2), global_batch=16)
    d0 = ctl.decide(None)
    assert d0.plan.new_shape == (4, 2) and d0.batch.accum_steps == 1
    # two nodes die: replanning shrinks data axis, doubles accumulation
    cluster.fail_node(6), cluster.fail_node(7)
    d1 = ctl.decide(None)
    assert d1.plan.new_shape == (2, 2) and d1.batch.accum_steps == 2
    assert d1.batch.microbatch * d1.batch.accum_steps == 16
    # while shrunk, no grow decision is volunteered
    assert ctl.decide(d1) is None
    # nodes rejoin: grow trigger fires
    cluster.join_node(6), cluster.join_node(7)
    d2 = ctl.decide(d1)
    assert d2 is not None and d2.plan.new_shape == (4, 2)
    assert d2.batch.accum_steps == 1
    # churn events were observed via the cluster watcher hook
    assert [e.kind for e in ctl.events] == ["fail", "fail", "join", "join"]


def test_controller_caps_growth_at_batch_divisibility():
    """Spare nodes must never grow the data axis past what the global batch
    can shard evenly (8 devices, batch 4 -> data axis capped at 4)."""
    cluster = Cluster(devices=list(range(8)))
    ctl = ChurnController(cluster, axes=("data", "model"),
                          base_shape=(1, 1), global_batch=4)
    d = ctl.decide(None)
    assert d.plan.new_shape == (4, 1)
    assert d.batch.microbatch % d.plan.new_shape[0] == 0


def test_controller_wait_for_capacity_times_out():
    cluster = Cluster(devices=list(range(2)))
    ctl = ChurnController(cluster, axes=("data", "model"),
                          base_shape=(1, 2), global_batch=4)
    cluster.fail_node(0)
    with pytest.raises(RuntimeError, match="model replica"):
        ctl.wait_for_capacity(timeout=0.2, poll=0.05)


# ------------------------------------------- accum equivalence (train step)

def test_accum_step_matches_full_batch_step():
    """One optimizer step with accum_steps=2 must match accum_steps=1 on the
    same global batch (grad averaging over equal microbatches == full-batch
    gradient) — the invariant elastic rescaling rests on."""
    from repro.runtime import steps as steps_mod
    from repro.models import params as pr
    from repro.optim import adamw

    cfg = registry.get_smoke("phi4-mini-3.8b")
    par = registry.get_parallel("phi4-mini-3.8b")
    shape = ShapeConfig("t", 32, 8, "train")
    mesh = single_device_mesh()
    batch = {"tokens": jnp.ones((8, 32), jnp.int32),
             "labels": jnp.arange(8 * 32, dtype=jnp.int32).reshape(8, 32) % 7}
    outs = {}
    for accum in (1, 2, 4):
        ocfg = OptimizerConfig(warmup_steps=2, decay_steps=100,
                               accum_steps=accum)
        bundle = steps_mod.build_train(cfg, par, ocfg, mesh, shape)
        assert bundle.accum_steps == accum
        mod = steps_mod._model_module(cfg)
        schema = mod.lm_schema(cfg)
        params = pr.init_params(schema, jax.random.key(0), cfg.param_dtype)
        opt = pr.init_params(adamw.opt_state_schema(schema, ocfg),
                             jax.random.key(1), "float32")
        with mesh:
            p, o, m = bundle.jit()(params, opt, batch)
        outs[accum] = (jax.device_get(m["loss"]),
                       np.asarray(jax.device_get(
                           jax.tree.leaves(p)[0]), dtype=np.float32))
    for accum in (2, 4):
        np.testing.assert_allclose(outs[accum][0], outs[1][0],
                                   rtol=2e-2, atol=2e-2)
        np.testing.assert_allclose(outs[accum][1], outs[1][1],
                                   rtol=5e-2, atol=5e-2)


def test_build_train_rejects_indivisible_accum():
    from repro.runtime import steps as steps_mod

    cfg = registry.get_smoke("phi4-mini-3.8b")
    par = registry.get_parallel("phi4-mini-3.8b")
    ocfg = OptimizerConfig(accum_steps=3)
    with pytest.raises(ValueError, match="accum_steps"):
        steps_mod.build_train(cfg, par, ocfg, single_device_mesh(),
                              ShapeConfig("t", 32, 8, "train"))


# ------------------------------------------------- launcher (thin wrapper)

def test_train_wrapper_degenerate_cluster(tmp_path):
    from repro.launch.train import train

    out = train("phi4-mini-3.8b", steps=6, seq=32, batch=4, smoke=True,
                ckpt_dir=str(tmp_path / "ck"), ckpt_every=2, log_every=3)
    assert len(out["losses"]) == 6
    assert out["params"] is not None
    rep = out["report"]
    assert rep.global_batch_constant
    assert [s.outcome for s in rep.segments] == ["done"]


def test_train_wrapper_self_heals_injected_crash(tmp_path):
    """--fail-at crashes once mid-run; the supervisor restores from the
    latest checkpoint and finishes IN THE SAME CALL (seed: raised)."""
    from repro.launch.train import train

    out = train("phi4-mini-3.8b", steps=8, seq=32, batch=4, smoke=True,
                ckpt_dir=str(tmp_path / "ck"), ckpt_every=2, fail_at=5,
                log_every=4)
    assert len(out["losses"]) == 8               # every step accounted for
    outcomes = [s.outcome for s in out["report"].segments]
    assert outcomes[0] == "error" and outcomes[-1] == "done"


def test_trainer_unschedulable_is_bounded(tmp_path):
    """A persistently unschedulable segment (pre-created namespace with a
    too-small quota) must error out after rejoin_timeout_s, not retry
    forever."""
    from repro.elastic import ElasticTrainer, ElasticTrainSpec

    cfg = registry.get_smoke("phi4-mini-3.8b")
    par = registry.get_parallel("phi4-mini-3.8b")
    cluster = Cluster(devices=jax.devices())
    cluster.create_namespace("elastic", device_quota=0)
    spec = ElasticTrainSpec(cfg, par, OptimizerConfig(), steps=4, seq_len=32,
                            global_batch=4, base_shape=(1, 1), max_data=1,
                            rejoin_timeout_s=0.5, verbose=False)
    trainer = ElasticTrainer(cluster, spec)
    with pytest.raises(RuntimeError, match="unschedulable"):
        trainer.run()


# ---------------------------------------------------- end-to-end churn run

@pytest.mark.slow
def test_elastic_trainer_survives_churn_e2e():
    """The acceptance scenario: 8 forced host devices, 2 killed mid-run,
    rejoin later — run continues from the latest checkpoint on the reshaped
    mesh with the global batch invariant.  Subprocess because the device
    count is an XLA flag fixed at jax init."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    out = subprocess.run(
        [sys.executable, os.path.join(ROOT, "examples",
                                      "elastic_failover.py"), "--fast"],
        env=env, capture_output=True, text=True, timeout=540)
    assert out.returncode == 0, f"\n{out.stdout}\n{out.stderr}"
    assert "CHURN_REPORT" in out.stdout
    assert "OK: self-healed" in out.stdout
