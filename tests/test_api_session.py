"""Session.apply drives every workload kind on every backend, and
Handle.cancel() drains cooperatively (training keeps its checkpoint)."""
import threading
import time

import jax
import pytest

from repro.api import (BatchJob, ServeJob, Session, TrainJob, WorkflowRun,
                       WorkloadState)
from repro.checkpoint.checkpoint import Checkpointer
from repro.core.orchestrator import Cluster
from repro.core.workflow import Step
from repro.data.objectstore import ObjectStore
from repro.fabric import Fabric, FederatedStore, PlacementPlanner
from repro.vcluster import FairShareScheduler, TenantSpec


def tiny_train(name, **kw):
    kw.setdefault("seq_len", 16)
    kw.setdefault("global_batch", 2)
    kw.setdefault("log_every", 1)
    kw.setdefault("verbose", False)
    return TrainJob(name=name, **kw)


# --------------------------------------------------------------- cluster
def test_cluster_batch_lifecycle_and_events():
    session = Session(cluster=Cluster(devices=jax.devices()))
    sub = session.bus.subscribe()
    handle = session.apply(BatchJob(name="hello", replicas=2),
                           fn=lambda ctx: f"hi-{ctx.pod_id}")
    out = handle.wait(60)
    assert sorted(out["results"]) == ["hi-hello-0", "hi-hello-1"]
    states = [e["state"] for e in handle.events()]
    assert states == ["Pending", "Placing", "Running", "Succeeded"]
    kinds = {(e.kind, e.data.get("state")) for e in sub.poll()}
    assert ("workload", "Succeeded") in kinds       # monitor-visible
    assert session.status()[0].state == WorkloadState.SUCCEEDED


def test_cluster_batch_entrypoint_and_cancel():
    session = Session(cluster=Cluster(devices=jax.devices()))
    # declarative fn: a manifest-only BatchJob
    h = session.apply({"kind": "BatchJob", "metadata": {"name": "decl"},
                       "spec": {"entrypoint": "builtins:repr"}})
    assert "PodCtx" in h.wait(60)["results"][0]

    # cancel: the pod drains cooperatively via the preempt signal
    def slowpoke(ctx):
        while not ctx.should_stop():
            time.sleep(0.01)
        return "drained"

    h2 = session.apply(BatchJob(name="slow"), fn=slowpoke)
    while h2.state != WorkloadState.RUNNING:
        time.sleep(0.01)
    time.sleep(0.05)
    assert h2.cancel(wait=True, timeout=60)
    assert h2.state == WorkloadState.CANCELLED
    assert h2.result()["results"] == ["drained"]
    assert not h2.cancel()                   # already terminal


def test_cluster_workflow_and_cancel(tmp_path):
    session = Session(cluster=Cluster(devices=jax.devices()),
                      store=ObjectStore(str(tmp_path)))
    ran = []

    def define(wf):
        wf.add(Step("a", lambda ctx: ran.append("a") or {"n": 1}))
        wf.add(Step("b", lambda ctx: ran.append("b") or {"n": 2},
                    deps=["a"]))

    out = session.apply(WorkflowRun(name="wf"), define=define).wait(60)
    assert ran == ["a", "b"]
    assert out["results"]["b"] == {"n": 2}
    assert [r.step for r in out["reports"]] == ["a", "b"]

    # cancel between steps: a completes, b never starts, markers persist
    gate = threading.Event()

    def define_slow(wf):
        wf.add(Step("a", lambda ctx: (gate.wait(10), {"n": 1})[1]))
        wf.add(Step("b", lambda ctx: ran.append("b2"), deps=["a"]))

    h = session.apply(WorkflowRun(name="wf2"), define=define_slow)
    while h.state != WorkloadState.RUNNING:
        time.sleep(0.01)
    h.cancel()
    gate.set()                       # step a finishes AFTER the cancel
    h.wait(60)
    assert h.state == WorkloadState.CANCELLED
    assert "b2" not in ran
    assert h.result()["results"] == {"a": {"n": 1}}
    # the completed step's marker survives -> a re-apply resumes past it
    store = ObjectStore(str(tmp_path))
    assert store.exists("workflows/wf2/a/_COMPLETE")


def test_cluster_train_cancel_preserves_checkpoint(tmp_path):
    """The acceptance contract: cancel() drains a RUNNING training
    workload to CANCELLED via the cooperative preempt path, and the
    goodbye checkpoint is there to resume from."""
    session = Session(cluster=Cluster(devices=jax.devices()))
    ckpt = str(tmp_path / "ckpt")
    h = session.apply(tiny_train("cancel-me", steps=500, ckpt_every=2,
                                 ckpt_dir=ckpt))
    while h.status().observed.get("step", -1) < 4:
        time.sleep(0.02)
    assert h.cancel(wait=True, timeout=120)
    assert h.state == WorkloadState.CANCELLED
    out = h.result()
    seg = out["report"].segments[-1]
    assert seg.outcome == "preempted"        # the cooperative drain path
    last = seg.end
    assert last < 499                        # it really stopped early
    # checkpoint preserved at (at least) the drained segment's last step
    ckpt_step = Checkpointer(ObjectStore(ckpt)).latest_step()
    assert ckpt_step == last, (ckpt_step, last)
    # ...and a fresh TrainJob resumes from it instead of step 0
    out2 = session.apply(tiny_train("resume", steps=last + 3,
                                    ckpt_dir=ckpt)).wait(300)
    assert out2["report"].segments[0].start == last + 1


# ---------------------------------------------------------------- fabric
def make_fabric():
    dev = jax.devices()[0]
    fabric = Fabric()
    fabric.add_site("big", cluster=Cluster(devices=[dev, dev, dev]))
    fabric.add_site("small", cluster=Cluster(devices=[dev]))
    fabric.connect("big", "small", gbps=10.0, latency_ms=1.0)
    return fabric


def test_fabric_batch_places_and_runs():
    fabric = make_fabric()
    session = Session(fabric=fabric)
    h = session.apply(BatchJob(name="fb", devices_per_pod=1),
                      fn=lambda ctx: ctx.site)
    out = h.wait(60)
    assert out["results"] == ["big"]         # least-loaded, most capacity
    assert out["site"] == "big"
    h2 = session.apply(BatchJob(name="pin", site="small"),
                       fn=lambda ctx: ctx.site)
    assert h2.wait(60)["results"] == ["small"]


def test_fabric_workflow_needs_planner_and_places():
    fabric = make_fabric()
    bare = Session(fabric=fabric)
    h = bare.apply(WorkflowRun(name="nope"), define=lambda wf: None)
    with pytest.raises(RuntimeError, match="planner"):
        h.wait(60)

    planner = PlacementPlanner(FederatedStore(fabric))
    session = Session(fabric=fabric, planner=planner)
    planner.fed.put("data/x", b"z" * 1024, "small")

    def define(wf):
        wf.add(Step("read", lambda ctx: {"n": len(ctx.store.get("data/x"))},
                    inputs=["data/x"]))

    out = session.apply(WorkflowRun(name="wf"), define=define).wait(60)
    assert out["results"]["read"] == {"n": 1024}
    assert out["reports"][0].site == "small"     # data-local placement


def test_fabric_serve_runs_as_placed_pod():
    fabric = make_fabric()
    session = Session(fabric=fabric)
    out = session.apply(ServeJob(
        name="fs", slots=2, prompt_len=8, max_new_tokens=4, site="small",
        requests=[{"id": i, "prompt": [1 + i] * 8, "max_new_tokens": 4}
                  for i in range(3)])).wait(300)
    assert out["site"] == "small"
    assert len(out["results"]) == 3
    assert out["report"].extra["requests"] == 3


def test_fabric_train_runs_elastic_federated():
    fabric = make_fabric()
    session = Session(fabric=fabric,
                      planner=PlacementPlanner(FederatedStore(fabric)))
    out = session.apply(tiny_train("fed", steps=4)).wait(600)
    assert len(out["losses"]) == 4
    assert out["sites"], "must record the hosting site"
    assert out["migrations"] == []


# ---------------------------------------------------------------- tenant
def make_sched():
    dev = jax.devices()[0]
    fabric = Fabric()
    fabric.add_site("s0", cluster=Cluster(devices=[dev, dev]))
    fabric.add_site("s1", cluster=Cluster(devices=[dev]))
    fabric.connect("s0", "s1", gbps=10.0, latency_ms=1.0)
    return FairShareScheduler(fed=FederatedStore(fabric),
                              reconcile_s=0.01)


def test_tenant_batch_serve_workflow():
    sched = make_sched()
    vc = sched.create_tenant(TenantSpec("alice"))
    session = Session(tenant=vc)
    with sched:
        out = session.apply(BatchJob(name="tb", devices_per_pod=1),
                            fn=lambda ctx: "ok").wait(60)
        assert out["results"] == ["ok"]

        # a queued job cancelled before placement dequeues cleanly
        blocker = session.apply(
            BatchJob(name="hog", devices_per_pod=2, site="s0"),
            fn=lambda ctx: time.sleep(0.5) or "hog")
        queued = session.apply(
            BatchJob(name="stuck", devices_per_pod=2, site="s0"),
            fn=lambda ctx: "never")
        time.sleep(0.1)
        queued.cancel(wait=True, timeout=30)
        assert queued.state == WorkloadState.CANCELLED
        assert queued.result()["results"] == []
        assert blocker.wait(60)["results"] == ["hog"]

        def define(wf):
            wf.add(Step("t", lambda ctx: {"tenant": ctx.namespace}))

        wout = session.apply(WorkflowRun(name="twf"),
                             define=define).wait(60)
        assert wout["results"]["t"] == {"tenant": "tenant-alice"}

        sout = session.apply(ServeJob(
            name="tserve", slots=2, prompt_len=8, max_new_tokens=4,
            requests=[{"id": i, "prompt": [1 + i] * 8,
                       "max_new_tokens": 4} for i in range(3)])).wait(300)
        assert len(sout["results"]) == 3
        assert all(len(v) == 4 for v in sout["results"].values())


def test_tenant_train_requires_site_and_devices():
    sched = make_sched()
    vc = sched.create_tenant(TenantSpec("bob"))
    session = Session(tenant=vc)
    h = session.apply(tiny_train("t", steps=2))
    with pytest.raises(RuntimeError, match="spec.site"):
        h.wait(60)


def test_apply_rejects_non_specs():
    session = Session(cluster=Cluster(devices=jax.devices()))
    with pytest.raises(Exception, match="Session.apply"):
        session.apply(42)


def test_session_requires_exactly_one_backend():
    with pytest.raises(TypeError, match="exactly one backend"):
        Session()
    with pytest.raises(TypeError, match="exactly one backend"):
        Session(cluster=Cluster(devices=jax.devices()),
                fabric=make_fabric())
