"""CONNECT case-study tests: labeling correctness (vs naive python
flood-fill, hypothesis-generated masks), object stats, FFN learning, and
the 4-step workflow end to end (with resume)."""
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="optional dev dependency (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.apps.connect import segment


def naive_label(mask: np.ndarray) -> np.ndarray:
    """Reference 6-connected labeling via BFS."""
    mask = mask.astype(bool)
    labels = np.zeros(mask.shape, np.int32)
    next_label = 0
    for idx in np.argwhere(mask):
        t, y, x = idx
        if labels[t, y, x]:
            continue
        next_label += 1
        stack = [(t, y, x)]
        labels[t, y, x] = next_label
        while stack:
            a, b, c = stack.pop()
            for da, db, dc in ((1, 0, 0), (-1, 0, 0), (0, 1, 0), (0, -1, 0),
                               (0, 0, 1), (0, 0, -1)):
                na, nb, nc = a + da, b + db, c + dc
                if (0 <= na < mask.shape[0] and 0 <= nb < mask.shape[1]
                        and 0 <= nc < mask.shape[2] and mask[na, nb, nc]
                        and not labels[na, nb, nc]):
                    labels[na, nb, nc] = next_label
                    stack.append((na, nb, nc))
    return labels


def canonical(labels: np.ndarray):
    """Partition signature independent of label values."""
    out = {}
    for v in np.unique(labels):
        if v == 0:
            continue
        out[v] = frozenset(map(tuple, np.argwhere(labels == v)))
    return frozenset(out.values())


@settings(max_examples=40, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_connect_label_matches_naive(seed):
    rng = np.random.RandomState(seed)
    mask = rng.rand(4, 6, 6) > 0.6
    ours = np.asarray(segment.connect_label(jnp.asarray(mask)))
    ref = naive_label(mask)
    assert (ours != 0).sum() == mask.sum()
    assert canonical(ours) == canonical(ref)


def test_connect_tracks_lifecycle_through_time():
    """An object moving through frames must be ONE object (the paper's whole
    point: connecting pixels in time AND space)."""
    mask = np.zeros((5, 8, 8), np.uint8)
    for t in range(5):                    # drifting blob, overlapping in time
        mask[t, 2:5, t:t + 3] = 1
    labels = np.asarray(segment.connect_label(jnp.asarray(mask)))
    stats = segment.object_stats(labels)
    assert len(stats) == 1
    assert stats[0]["genesis_frame"] == 0
    assert stats[0]["termination_frame"] == 4
    assert stats[0]["duration"] == 5
    assert stats[0]["drift"] > 0


def test_two_separate_events_are_two_objects():
    mask = np.zeros((4, 10, 10), np.uint8)
    mask[0:2, 1:3, 1:3] = 1
    mask[2:4, 7:9, 7:9] = 1               # disjoint in space AND time
    labels = np.asarray(segment.connect_label(jnp.asarray(mask)))
    assert len(segment.object_stats(labels)) == 2


def test_ffn_learns_and_workflow_resumes(tmp_path):
    from repro.apps.connect.pipeline import ConnectConfig, build_workflow
    from repro.core.orchestrator import Cluster
    from repro.data.objectstore import ObjectStore
    from repro.data.volumes import VolumeSpec
    from repro.models.ffn3d import FFNConfig

    cc = ConnectConfig(
        n_chunks=1, download_workers=2, inference_workers=2,
        vol=VolumeSpec(lat=32, lon=48, frames=8, events=2),
        ffn=FFNConfig(depth=2, width=8, fov=(8, 16, 16), flood_iters=2),
        train_steps=15)
    cluster = Cluster()
    cluster.create_namespace("atmos-science")
    store = ObjectStore(str(tmp_path))
    wf = build_workflow(cluster, store, cc)
    results = wf.run()
    assert results["train"]["last_loss"] < results["train"]["first_loss"]
    assert results["inference"]["voxels"] > 0
    assert "objects" in results["analyze"]

    # resume: a fresh workflow over the same store skips all four steps
    wf2 = build_workflow(Cluster(metrics=None), store, cc)
    wf2.cluster.create_namespace if False else None
    results2 = wf2.run()
    assert results2["analyze"] == results["analyze"]
    assert wf2.reports == []              # nothing re-executed
