"""Continuous-batching serving tests.

Scheduler policy (admission order, slot reuse, lease renewal, metrics) is
exercised with a fake clock and a fake engine — fully deterministic, no
devices.  The per-slot decode step and slotted-cache plumbing are checked
numerically on the smoke config, and one end-to-end serve run compares
continuous results against the engine-level invariants.
"""
import numpy as np
import pytest

from repro.core.metrics import Registry
from repro.core.queue import WorkQueue
from repro.serving.scheduler import ContinuousScheduler, Request


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def mk_requests(gens, prompt=(5, 6, 7)):
    return [{"id": i, "prompt": list(prompt), "max_new_tokens": g}
            for i, g in enumerate(gens)]


def fake_serve(queue, num_slots, *, clock, step_cost=1.0, prefill_pos=8,
               renew=True, registry=None):
    """Drive a scheduler with a fake engine: token ids are synthesized,
    every fused decode step advances the fake clock by ``step_cost``."""
    sched = ContinuousScheduler(queue, num_slots, registry=registry,
                                clock=clock)
    trace = {"admitted": [], "completed": []}
    while True:
        for slot in sched.admit():
            trace["admitted"].append((slot.request.rid, slot.index))
            done = sched.start(slot, 1000 + slot.request.rid, prefill_pos)
            trace["completed"] += [rid for rid, _ in done]
        if not sched.active():
            if sched.finished():
                break
            clock.advance(step_cost)
            continue
        clock.advance(step_cost)
        toks = [1000 + s.request.rid if not s.free else 0
                for s in sched.slots]
        done = sched.observe(toks)
        trace["completed"] += [rid for rid, _ in done]
        if renew:
            sched.renew_leases()
    return sched, trace


# ------------------------------------------------------------- scheduler

def test_admission_is_fifo_and_fills_free_slots():
    clock = FakeClock()
    q = WorkQueue(mk_requests([3] * 5), clock=clock)
    sched, trace = fake_serve(q, 2, clock=clock)
    # requests admitted in queue order
    assert [rid for rid, _ in trace["admitted"]] == [0, 1, 2, 3, 4]
    assert len(sched.results()) == 5
    assert q.completed == 5 and q.drained()


def test_slot_reuse_after_early_stop():
    """A short request frees its slot, which the next queued request
    reuses immediately — while the long request keeps decoding."""
    clock = FakeClock()
    q = WorkQueue(mk_requests([10, 2, 2, 2]), clock=clock)
    sched, trace = fake_serve(q, 2, clock=clock)
    admitted = dict(trace["admitted"])           # rid -> slot index
    # r0 holds slot 0 throughout; r1, r2, r3 cycle through slot 1
    assert admitted[0] == 0
    assert admitted[1] == admitted[2] == admitted[3] == 1
    # short requests complete long before the straggler
    assert trace["completed"][:3] == [1, 2, 3]
    assert trace["completed"][-1] == 0
    # every request got exactly its stop length
    assert {rid: len(t) for rid, t in sched.results().items()} == \
        {0: 10, 1: 2, 2: 2, 3: 2}


def test_stop_length_one_completes_at_prefill():
    clock = FakeClock()
    q = WorkQueue(mk_requests([1, 1, 3]), clock=clock)
    sched, trace = fake_serve(q, 2, clock=clock)
    assert sched.results()[0] == [1000]
    assert sched.results()[1] == [1001]
    assert len(sched.results()[2]) == 3


def test_lease_renewal_keeps_slow_decode_leased():
    """A request that decodes longer than the visibility timeout survives
    because the scheduler heartbeats the lease between steps."""
    clock = FakeClock()
    q = WorkQueue(mk_requests([50]), lease_timeout=10.0, clock=clock)
    sched, _ = fake_serve(q, 1, clock=clock, step_cost=1.0)
    # 50 steps at 1s each >> 10s timeout; renewals must have happened and
    # the task must have completed on the FIRST attempt (never reclaimed)
    assert q.completed == 1
    assert len(sched.results()[0]) == 50
    s = sched.metrics.summary()
    assert s["serve/lease_renewals"]["total"] >= 4
    assert "serve/lease_lost" not in s
    assert "serve/stale_ack" not in s


def test_without_renewal_lease_expires_and_slot_dropped():
    clock = FakeClock()
    q = WorkQueue(mk_requests([50]), lease_timeout=10.0, clock=clock)
    sched = ContinuousScheduler(q, 1, clock=clock)
    [slot] = sched.admit()
    sched.start(slot, 1000, 8)
    clock.advance(11.0)                 # lease expires, never renewed
    assert q.lease("thief") is not None  # another worker reclaims the task
    assert sched.renew_leases() == 0     # renewal fails...
    assert sched.slots[0].free           # ...and the slot is dropped un-acked
    assert sched.metrics.summary()["serve/lease_lost"]["total"] == 1


def test_queue_renew_semantics():
    clock = FakeClock()
    q = WorkQueue([{"id": 0, "prompt": [1]}], lease_timeout=10.0, clock=clock)
    tid, _ = q.lease("w")
    assert not q.renew(tid, "other")        # wrong worker
    assert not q.renew(99, "w")             # unknown task
    clock.advance(8.0)
    assert q.renew(tid, "w")                # extends to t=18
    clock.advance(8.0)                      # t=16 < 18: still leased
    assert q.lease("thief") is None
    assert q.ack(tid, "w")
    clock.advance(100.0)
    assert q.drained()
    assert not q.renew(tid, "w")            # done tasks can't renew


def test_metrics_totals_under_fake_clock():
    clock = FakeClock()
    reg = Registry()
    gens = [4, 2, 3, 1]
    q = WorkQueue(mk_requests(gens), clock=clock)
    sched, _ = fake_serve(q, 2, clock=clock, step_cost=1.0, registry=reg)
    s = reg.summary()
    assert s["serve/admitted"]["total"] == 4
    assert s["serve/completed"]["total"] == 4
    assert s["serve/tokens_generated"]["total"] == sum(gens)
    # fused steps: slots {r0:4, r2:3} and {r1:2, r3:1} -> longest chain
    # drives the step count; occupancy is per-step active slots
    assert s["serve/decode_steps"]["total"] == s["serve/slot_occupancy"]["count"]
    assert s["serve/slot_occupancy"]["max"] <= 2
    # latency = admit -> completion on the same fake clock: r1 (2 tokens,
    # admitted at t=0, completes after its 1 decode step at t=1)
    assert s["serve/request_latency_s"]["p50"] >= 1.0
    assert s["serve/ttft_s"]["count"] == 4


def test_request_from_item_defaults():
    r = Request.from_item(7, {"prompt": [1, 2]}, default_max_new=5)
    assert r.rid == 7 and r.max_new_tokens == 5
    r2 = Request.from_item(0, Request(rid="x", prompt=(1,), max_new_tokens=2))
    assert r2.rid == "x"


# ------------------------------------------------- slotted cache / decode

@pytest.fixture(scope="module")
def smoke_setup():
    import jax
    from repro.configs import registry
    from repro.configs.base import ShapeConfig
    from repro.launch.mesh import single_device_mesh
    from repro.models import params as pr
    from repro.runtime import steps as steps_mod

    arch = "phi4-mini-3.8b"
    cfg = registry.get_smoke(arch)
    par = registry.get_parallel(arch)
    mesh = single_device_mesh()
    Pp, G, B = 8, 4, 2
    S = Pp + G
    cfg = steps_mod.resolve_cfg(cfg, ShapeConfig("s", S, B, "prefill"))
    mod = steps_mod._model_module(cfg)
    params = pr.init_params(mod.lm_schema(cfg), jax.random.key(0),
                            cfg.param_dtype)
    return dict(cfg=cfg, par=par, mesh=mesh, params=params,
                Pp=Pp, G=G, B=B, S=S)


def test_slot_decode_matches_scalar_decode(smoke_setup):
    """Vector-position decode with all rows at the same position must equal
    the classic scalar-position whole-batch decode, token for token."""
    import jax
    import jax.numpy as jnp
    from repro.configs.base import ShapeConfig
    from repro.runtime import steps as steps_mod

    s = smoke_setup
    cfg, par, mesh, params = s["cfg"], s["par"], s["mesh"], s["params"]
    Pp, G, B, S = s["Pp"], s["G"], s["B"], s["S"]
    prefill = steps_mod.build_prefill(
        cfg, par, mesh, ShapeConfig("s", S, B, "prefill")).jit()
    dec_s = steps_mod.build_decode(
        cfg, par, mesh, ShapeConfig("s", S, B, "decode")).jit()
    dec_v = steps_mod.build_slot_decode(
        cfg, par, mesh, ShapeConfig("s", S, B, "decode")).jit()

    rng = np.random.RandomState(0)
    prompts = rng.randint(1, cfg.vocab_size, (B, Pp)).astype(np.int32)
    with mesh:
        last, small = prefill(params, jnp.asarray(prompts))
        tok = jnp.argmax(last, -1).astype(jnp.int32)[:, None]
        pad = jax.jit(steps_mod.cache_prefix_insert)
        cache_a = pad(steps_mod.init_cache(cfg, B, S), small)
        cache_b = pad(steps_mod.init_cache(cfg, B, S), small)
        ta, tb = tok, tok
        for g in range(G):
            ta, cache_a = dec_s(params, cache_a, ta, jnp.int32(Pp + g))
            tb, cache_b = dec_v(params, cache_b, tb,
                                jnp.full((B,), Pp + g, jnp.int32))
            np.testing.assert_array_equal(np.asarray(ta), np.asarray(tb))


def test_slot_isolation_and_reuse(smoke_setup):
    """A request decoded alone in slot 1 (slot 0 idle, then refilled with a
    different request mid-flight) produces the same tokens as in the
    all-rows-equal batched run — slots are independent."""
    import jax
    import jax.numpy as jnp
    from repro.configs.base import ShapeConfig
    from repro.runtime import steps as steps_mod

    s = smoke_setup
    cfg, par, mesh, params = s["cfg"], s["par"], s["mesh"], s["params"]
    Pp, G, B, S = s["Pp"], s["G"], s["B"], s["S"]
    prefill1 = steps_mod.build_prefill(
        cfg, par, mesh, ShapeConfig("s", S, 1, "prefill")).jit()
    dec_v = steps_mod.build_slot_decode(
        cfg, par, mesh, ShapeConfig("s", S, B, "decode")).jit()

    rng = np.random.RandomState(0)
    p0 = rng.randint(1, cfg.vocab_size, (1, Pp)).astype(np.int32)
    p1 = rng.randint(1, cfg.vocab_size, (1, Pp)).astype(np.int32)

    def solo_reference(prompt):
        last, small = prefill1(params, jnp.asarray(prompt))
        tok = jnp.argmax(last, -1).astype(jnp.int32)[:, None]
        cache = steps_mod.cache_batch_insert(
            steps_mod.init_cache(cfg, B, S), small, 0)
        toks, pos = [int(tok[0, 0])], np.array([Pp, 0], np.int32)
        t = jnp.concatenate([tok, jnp.zeros((B - 1, 1), jnp.int32)])
        for _ in range(G - 1):
            t, cache = dec_v(params, cache, t, jnp.asarray(pos))
            toks.append(int(t[0, 0]))
            pos[0] += 1
        return toks

    with mesh:
        ref0 = solo_reference(p0)
        ref1 = solo_reference(p1)

        # now interleave: r0 in slot 0; after 2 steps admit r1 into slot 1
        last, small = prefill1(params, jnp.asarray(p0))
        cache = steps_mod.cache_batch_insert(
            steps_mod.init_cache(cfg, B, S), small, 0)
        t = jnp.concatenate(
            [jnp.argmax(last, -1).astype(jnp.int32)[:, None],
             jnp.zeros((B - 1, 1), jnp.int32)])
        pos = np.array([Pp, 0], np.int32)
        got0 = [int(t[0, 0])]
        for _ in range(2):
            t, cache = dec_v(params, cache, t, jnp.asarray(pos))
            got0.append(int(t[0, 0]))
            pos[0] += 1
        # admit r1 into slot 1 mid-flight
        last1, small1 = prefill1(params, jnp.asarray(p1))
        cache = steps_mod.cache_batch_insert(cache, small1, 1)
        t = jnp.stack([t[0], jnp.argmax(last1[0], -1).astype(jnp.int32)[None]])
        pos[1] = Pp
        got1 = [int(t[1, 0])]
        for _ in range(G - 1):
            t, cache = dec_v(params, cache, t, jnp.asarray(pos))
            if len(got0) < G:
                got0.append(int(t[0, 0]))
            got1.append(int(t[1, 0]))
            pos += 1
    assert got0 == ref0          # r0 unaffected by the mid-flight admission
    assert got1 == ref1          # r1 unaffected by r0's occupancy


def test_cache_insert_evict_roundtrip():
    import jax.numpy as jnp
    from repro.runtime import steps as steps_mod

    big = {"k": jnp.zeros((2, 3, 4, 2, 2)), "s": jnp.zeros((2, 3, 5))}
    small = {"k": jnp.ones((2, 1, 2, 2, 2)),   # shorter seq axis than dst
             "s": jnp.ones((2, 1, 5))}
    out = steps_mod.cache_batch_insert(big, small, 1)
    assert float(out["k"][:, 1, :2].min()) == 1.0
    assert float(out["k"][:, 1, 2:].max()) == 0.0   # tail untouched
    assert float(out["k"][:, 0].max()) == 0.0       # other slots untouched
    assert float(out["s"][:, 1].min()) == 1.0
    out = steps_mod.cache_batch_evict(out, 1)
    assert float(out["k"].max()) == 0.0 and float(out["s"].max()) == 0.0


# ------------------------------------------------------------ end to end

def test_continuous_serve_end_to_end():
    """Heterogeneous stop lengths through the real engine on the smoke
    config: every request completes at exactly its stop length and the
    metrics totals agree with the results."""
    from repro.launch.serve import serve

    gens = [6, 2, 4, 1, 6]
    results, metrics = serve("phi4-mini-3.8b", smoke=True, n_requests=5,
                             prompt_len=8, gen=6, batch=2, gen_lens=gens)
    assert sorted(results) == [0, 1, 2, 3, 4]
    assert [len(results[i]) for i in range(5)] == gens
    s = metrics.summary()
    assert s["serve/completed"]["total"] == 5
    assert s["serve/tokens_generated"]["total"] == sum(gens)
    assert s["serve/slot_occupancy"]["max"] <= 2
    assert s["serve/request_latency_s"]["count"] == 5


def test_engine_preemption_stops_cleanly_and_requeues():
    """A preempted serving pod (repro.vcluster) exits between fused
    steps without acking in-flight work: those leases expire back to the
    queue, and a re-placed engine serves every request to completion."""
    from repro.configs import registry
    from repro.core.queue import WorkQueue
    from repro.launch.mesh import single_device_mesh
    from repro.serving import ServingEngine

    cfg = registry.get_smoke("phi4-mini-3.8b")
    par = registry.get_parallel("phi4-mini-3.8b")
    mesh = single_device_mesh()
    reqs = [{"id": i, "prompt": [1 + i] * 4, "max_new_tokens": 3}
            for i in range(4)]
    queue = WorkQueue(reqs, lease_timeout=0.05)

    engine = ServingEngine(cfg, par, mesh, num_slots=2, prompt_len=4,
                           max_new_tokens=3)
    calls = {"n": 0}

    def stop_after_two():
        calls["n"] += 1
        return calls["n"] > 2           # a couple of steps, then evicted

    results, metrics = engine.run(queue, should_stop=stop_after_two)
    assert metrics.series("serve/preempted").points
    assert len(results) < 4             # interrupted mid-stream
    assert not queue.drained()
    # "re-placed" engine (fresh slots/caches) picks up the expired leases
    import time as _t
    _t.sleep(0.06)                      # let the in-flight leases expire
    engine2 = ServingEngine(cfg, par, mesh, num_slots=2, prompt_len=4,
                            max_new_tokens=3)
    results2, _ = engine2.run(queue)
    done = dict(results)
    done.update(results2)
    assert sorted(done) == [0, 1, 2, 3]
    assert all(len(v) == 3 for v in done.values())
    assert queue.drained()


def test_continuous_serve_audio_family():
    """Enc-dec (whisper) serving: the decoder-position table is the self
    cache, so the engine must budget prompt + generation inside
    decoder_len — a regression here silently no-ops every generated
    token's K/V write."""
    from repro.launch.serve import serve

    gens = [4, 2, 1]
    results, metrics = serve("whisper-small", smoke=True, n_requests=3,
                             prompt_len=8, gen=4, batch=2, gen_lens=gens)
    assert [len(results[i]) for i in range(3)] == gens
    assert metrics.summary()["serve/tokens_generated"]["total"] == sum(gens)
