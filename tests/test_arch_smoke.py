"""Per-architecture smoke tests: instantiate a REDUCED config of the same
family and run one train step + one prefill + one decode step on CPU,
asserting output shapes and finiteness (no NaNs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import OptimizerConfig, ShapeConfig
from repro.launch.mesh import single_device_mesh
from repro.models import params as pr
from repro.optim import adamw
from repro.runtime import steps as steps_mod

ARCHS = registry.ARCHS

B, S = 2, 32


def _build(arch):
    cfg = registry.get_smoke(arch)
    par = registry.get_parallel(arch)
    ocfg = OptimizerConfig(warmup_steps=2, decay_steps=10,
                           moment_dtype=registry.get_optimizer(arch).moment_dtype,
                           second_moment=registry.get_optimizer(arch).second_moment)
    mesh = single_device_mesh()
    return cfg, par, ocfg, mesh


def _init(cfg, mod, ocfg):
    schema = mod.lm_schema(cfg)
    params = pr.init_params(schema, jax.random.key(0), cfg.param_dtype)
    opt = pr.init_params(adamw.opt_state_schema(schema, ocfg),
                         jax.random.key(1), "float32")
    return params, opt


def _batch(cfg, shape):
    rng = np.random.RandomState(0)
    T = steps_mod.token_len(cfg, shape)
    batch = {
        "tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, T)), jnp.int32),
        "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, T)), jnp.int32),
    }
    ex_abs, _ = steps_mod.extras_specs(cfg, B)
    if ex_abs:
        batch["extras"] = {k: jnp.asarray(rng.randn(*v.shape), v.dtype)
                           for k, v in ex_abs.items()}
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    cfg, par, ocfg, mesh = _build(arch)
    shape = ShapeConfig("t", S, B, "train")
    cfg = steps_mod.resolve_cfg(cfg, shape)
    bundle = steps_mod.build_train(cfg, par, ocfg, mesh, shape)
    mod = steps_mod._model_module(cfg)
    params, opt = _init(cfg, mod, ocfg)
    batch = _batch(cfg, shape)
    with mesh:
        new_params, new_opt, metrics = bundle.jit()(params, opt, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), f"{arch}: loss={loss}"
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    before = jax.tree.leaves(params)[0]
    after = jax.tree.leaves(new_params)[0]
    assert after.shape == before.shape


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode(arch):
    cfg, par, ocfg, mesh = _build(arch)
    shape = ShapeConfig("p", S, B, "prefill")
    cfg = steps_mod.resolve_cfg(cfg, shape)
    mod = steps_mod._model_module(cfg)
    params, _ = _init(cfg, mod, ocfg)
    batch = _batch(cfg, shape)
    pb = steps_mod.build_prefill(cfg, par, mesh, shape)
    with mesh:
        args = (params, batch["tokens"]) + ((batch["extras"],)
                                            if "extras" in batch else ())
        last, caches = pb.jit()(*args)
    assert last.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(last, np.float32)).all(), arch

    db = steps_mod.build_decode(cfg, par, mesh,
                                ShapeConfig("d", S, B, "decode"))
    tok = jnp.argmax(last, -1).astype(jnp.int32)[:, None]
    T = steps_mod.token_len(cfg, shape)
    with mesh:
        nxt, caches2 = db.jit()(params, caches, tok, jnp.int32(T - 1))
    assert nxt.shape == (B, 1)
    assert (np.asarray(nxt) >= 0).all() and (np.asarray(nxt) < cfg.vocab_size).all()
