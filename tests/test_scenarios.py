"""Production-chaos scenario harness: traffic-generator determinism,
chaos-schedule validation, SLO grading, and the slow end-to-end chaos
regression (site kill + link brown-out mid-run, graded tenants)."""
import threading
import time

import numpy as np
import pytest

from repro.scenarios import (SLO, BurstOverlay, ChaosEvent, ChaosInjector,
                             ChaosSchedule, DiurnalRate, Price, ScenarioSpec,
                             ServePlan, TrafficShape, TrainPlan, chargeback,
                             grade_table, grade_tenant, run_scenario,
                             slice_window)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:              # optional dev dependency
    HAVE_HYPOTHESIS = False


# ------------------------------------------------------- traffic generators

def check_same_seed_same_trace(shape):
    """The replay contract: one seed == one trace, bit for bit — arrivals,
    lengths and the fully rendered request list."""
    horizon = shape.rate.period_s
    a1, a2 = shape.arrivals(horizon), shape.arrivals(horizon)
    assert np.array_equal(a1, a2)
    assert np.array_equal(shape.prompt_lengths(64), shape.prompt_lengths(64))
    assert np.array_equal(shape.gen_lengths(64), shape.gen_lengths(64))
    r1 = shape.requests(horizon, vocab_size=128)
    r2 = shape.requests(horizon, vocab_size=128)
    assert r1 == r2


def check_arrival_count_tracks_mean_rate(shape):
    """Over one full diurnal period the Poisson count concentrates around
    mean_rps * period (6-sigma + slack tolerance, so it never flakes)."""
    horizon = shape.rate.period_s
    arrivals = shape.arrivals(horizon)
    assert all(0.0 <= t < horizon for t in arrivals)
    assert list(arrivals) == sorted(arrivals)
    expected = shape.mean_rps() * horizon
    tol = 6.0 * np.sqrt(expected) + 10.0
    assert abs(len(arrivals) - expected) <= tol, \
        f"{len(arrivals)} arrivals vs expected {expected:.1f} (tol {tol:.1f})"


def check_lengths_always_in_bounds(shape, n):
    """Heavy tails are clamped: Zipf prompts in [1, max_prompt_len],
    lognormal gen lengths in [1, max_new_tokens] — never 0, never over."""
    p = shape.prompt_lengths(n)
    g = shape.gen_lengths(n)
    assert p.min() >= 1 and p.max() <= shape.max_prompt_len
    assert g.min() >= 1 and g.max() <= shape.max_new_tokens
    for r in shape.requests(shape.rate.period_s, vocab_size=64):
        assert 1 <= len(r["prompt"]) <= shape.max_prompt_len
        assert 1 <= r["max_new_tokens"] <= shape.max_new_tokens
        assert all(0 <= tok < 64 for tok in r["prompt"])


def fixed_shape(seed=0, max_prompt_len=24, max_new_tokens=12):
    """Deterministic fallback when hypothesis is absent: still exercises
    every traffic invariant, just on fixed parameters."""
    return TrafficShape(
        name="t",
        rate=DiurnalRate(base_rps=0.8, peak_rps=3.2, period_s=120.0,
                         phase_s=30.0),
        zipf_a=1.6, max_prompt_len=max_prompt_len,
        max_new_tokens=max_new_tokens, seed=seed)


@pytest.mark.parametrize("seed", [0, 7, 12345])
def test_traffic_invariants_fixed_seeds(seed):
    shape = fixed_shape(seed=seed)
    check_same_seed_same_trace(shape)
    check_arrival_count_tracks_mean_rate(shape)
    check_lengths_always_in_bounds(shape, 256)


def test_different_seed_different_trace():
    a = fixed_shape(seed=1).arrivals(120.0)
    b = fixed_shape(seed=2).arrivals(120.0)
    assert not np.array_equal(a, b)


if HAVE_HYPOTHESIS:
    @st.composite
    def shapes(draw):
        """Burst-free diurnal shapes with rates high enough that the
        mean-count property has statistical teeth."""
        base = draw(st.floats(min_value=0.5, max_value=5.0))
        peak = draw(st.floats(min_value=0.5, max_value=5.0))
        period = draw(st.floats(min_value=50.0, max_value=200.0))
        return TrafficShape(
            name="t",
            rate=DiurnalRate(base_rps=min(base, peak),
                             peak_rps=max(base, peak),
                             period_s=period,
                             phase_s=draw(st.floats(min_value=0.0,
                                                    max_value=period))),
            zipf_a=draw(st.floats(min_value=1.2, max_value=3.0)),
            max_prompt_len=draw(st.integers(min_value=1, max_value=64)),
            max_new_tokens=draw(st.integers(min_value=1, max_value=64)),
            seed=draw(st.integers(min_value=0, max_value=2**20)))

    @settings(max_examples=60, deadline=None)
    @given(shape=shapes())
    def test_same_seed_same_trace(shape):
        check_same_seed_same_trace(shape)

    @settings(max_examples=60, deadline=None)
    @given(shape=shapes())
    def test_arrival_count_tracks_mean_rate(shape):
        check_arrival_count_tracks_mean_rate(shape)

    @settings(max_examples=60, deadline=None)
    @given(shape=shapes(), n=st.integers(min_value=1, max_value=256))
    def test_lengths_always_in_bounds(shape, n):
        check_lengths_always_in_bounds(shape, n)


def test_burst_overlay_raises_mean_rate():
    base = DiurnalRate(base_rps=1.0, peak_rps=1.0, period_s=100.0)
    quiet = TrafficShape(name="q", rate=base, seed=3)
    bursty = TrafficShape(name="b", rate=base, seed=3,
                          bursts=BurstOverlay(rate_per_s=0.05, extra_rps=4.0,
                                              duration_s=10.0))
    assert bursty.mean_rps() > quiet.mean_rps()
    assert bursty.max_rps() >= quiet.max_rps() + 4.0


def test_slice_window_partitions_trace():
    shape = TrafficShape(
        name="w", rate=DiurnalRate(base_rps=2.0, peak_rps=2.0,
                                   period_s=60.0), seed=1)
    reqs = shape.requests(60.0, vocab_size=32)
    parts = [slice_window(reqs, w * 20.0, (w + 1) * 20.0) for w in range(3)]
    assert sum(len(p) for p in parts) == len(reqs)
    assert [r["id"] for p in parts for r in p] == [r["id"] for r in reqs]


# ------------------------------------------------------- chaos validation

def check_alternating_failures_validate(events):
    sched = ChaosSchedule(events)
    assert len(sched.events) == len(events)
    # ...and injecting a second failure inside any open window is rejected
    kill = next(e for e in sched.events if e.kind == "site-kill")
    dup = ChaosEvent(at_s=kill.at_s + 0.5, kind="site-kill", site=kill.site)
    with pytest.raises(ValueError, match="overlapping"):
        ChaosSchedule(events + [dup])
    # ...unless overlap is explicitly permitted
    ChaosSchedule(events + [dup], allow_overlap=True)


def test_sequential_failures_validate():
    """kill -> restore -> kill again on one site is a well-formed
    schedule; a second kill inside the open window is not."""
    events = []
    for site, t0 in (("s0", 0.0), ("s1", 5.5)):
        for k in range(3):
            events.append(ChaosEvent(at_s=t0 + 2 * k, kind="site-kill",
                                     site=site))
            events.append(ChaosEvent(at_s=t0 + 2 * k + 1,
                                     kind="site-restore", site=site))
    check_alternating_failures_validate(events)


if HAVE_HYPOTHESIS:
    @st.composite
    def alternating_schedules(draw):
        """Well-formed schedules: per target, strictly alternating
        fail -> restore pairs (any number, any start time)."""
        events = []
        for i in range(draw(st.integers(min_value=1, max_value=3))):
            site = f"s{i}"
            t0 = draw(st.floats(min_value=0.0, max_value=100.0))
            for k in range(draw(st.integers(min_value=1, max_value=3))):
                events.append(ChaosEvent(at_s=t0 + 2 * k, kind="site-kill",
                                         site=site))
                events.append(ChaosEvent(at_s=t0 + 2 * k + 1,
                                         kind="site-restore", site=site))
        return events

    @settings(max_examples=60, deadline=None)
    @given(events=alternating_schedules())
    def test_alternating_failures_always_validate(events):
        check_alternating_failures_validate(events)


def test_overlap_rules_per_target():
    kill = ChaosEvent(at_s=10, kind="site-kill", site="a")
    # distinct sites may fail concurrently
    ChaosSchedule([kill, ChaosEvent(at_s=11, kind="site-kill", site="b")])
    # node-fail while the same site is killed is an overlap...
    with pytest.raises(ValueError, match="overlapping"):
        ChaosSchedule([kill, ChaosEvent(at_s=11, kind="node-fail",
                                        site="a")])
    # ...but a link brown-out is a different target even if it names "a"
    ChaosSchedule([kill, ChaosEvent(at_s=11, kind="link-degrade",
                                    link=("a", "b"), gbps=0.1)])
    # double brown-out of one link (either endpoint order) is an overlap
    with pytest.raises(ValueError, match="overlapping"):
        ChaosSchedule([
            ChaosEvent(at_s=1, kind="link-degrade", link=("a", "b"),
                       gbps=0.1),
            ChaosEvent(at_s=2, kind="link-degrade", link=("b", "a"),
                       gbps=0.2)])


def test_event_field_validation():
    with pytest.raises(ValueError, match="unknown chaos kind"):
        ChaosEvent(at_s=0, kind="meteor", site="a")
    with pytest.raises(ValueError, match="at_s"):
        ChaosEvent(at_s=-1, kind="site-kill", site="a")
    with pytest.raises(ValueError, match="needs site"):
        ChaosEvent(at_s=0, kind="node-fail")
    with pytest.raises(ValueError, match="needs link"):
        ChaosEvent(at_s=0, kind="link-degrade", gbps=1.0)
    with pytest.raises(ValueError, match="gbps"):
        ChaosEvent(at_s=0, kind="link-degrade", link=("a", "b"))


def test_injector_fires_each_event_exactly_once():
    from repro.fabric import Fabric
    fabric = Fabric()
    fabric.add_site("a", devices=[0, 1])
    fabric.add_site("b", devices=[0])
    fabric.connect("a", "b", gbps=1.0, latency_ms=1.0)
    inj = ChaosInjector(fabric, ChaosSchedule([
        ChaosEvent(at_s=5, kind="node-fail", site="a"),
        ChaosEvent(at_s=10, kind="site-kill", site="b"),
        ChaosEvent(at_s=20, kind="node-join", site="a"),
        ChaosEvent(at_s=30, kind="site-restore", site="b"),
    ]))
    assert [r["kind"] for r in inj.fire_due(10)] == ["node-fail",
                                                     "site-kill"]
    assert len(fabric.sites["a"].cluster.online_devices) == 1
    assert not fabric.sites["b"].up
    assert inj.fire_due(10) == []            # idempotent
    late = inj.fire_due(1e9)
    assert [r["kind"] for r in late] == ["node-join", "site-restore"]
    assert all(r["applied"] for r in inj.fired)
    assert len(fabric.sites["a"].cluster.online_devices) == 2
    assert fabric.sites["b"].up


# ---------------------------------------------------------------- grading

def test_grade_tenant_verdicts_and_chargeback():
    g = grade_tenant(
        "chat", SLO(p99_ttft_s=1.0, p99_latency_s=2.0, min_goodput=0.9),
        offered=100, served=95, ttft_s=[0.1] * 90 + [5.0] * 10,
        latency_s=[0.2] * 100, horizon_s=100.0,
        price=Price(per_gb=1.0, per_device_s=0.01),
        bytes_moved=2e9, device_s=50.0)
    assert g.rejected == 5
    assert g.goodput_ratio == pytest.approx(0.95)
    assert g.verdicts == {"p99_ttft": False, "p99_latency": True,
                          "goodput": True}
    assert not g.slo_pass                      # one verdict fails => fail
    assert g.chargeback["gb_moved"] == pytest.approx(2.0)
    assert g.chargeback["total"] == pytest.approx(2.0 + 0.5)
    assert "chat" in grade_table([g])
    row = g.to_json()
    assert row["offered"] == 100 and row["slo_pass"] is False


def test_grade_rejects_overcounted_served():
    with pytest.raises(ValueError, match="served"):
        grade_tenant("t", SLO(), offered=1, served=2, horizon_s=10.0)


def test_chargeback_zero_usage_is_free():
    bill = chargeback(Price(), bytes_moved=0.0, device_s=0.0)
    assert bill["total"] == 0.0


# ------------------------------------------- end-to-end chaos regression

@pytest.mark.slow
def test_scenario_survives_site_kill_and_preemption():
    """Tiny diurnal run through the declarative surface: the serving
    site is killed mid-wave and a gated priority burst preempts the
    trainer exactly once.  The run must terminate, every tenant must be
    graded with nothing silently dropped, and the elastic bound must
    hold strictly (steps_lost <= ckpt_every)."""
    import jax

    from repro.api import ServeJob, TrainJob
    from repro.core.orchestrator import Cluster, JobSpec
    from repro.fabric import Fabric, FederatedStore
    from repro.vcluster import FairShareScheduler, TenantSpec

    fabric = Fabric()
    fabric.add_site("gpu", cluster=Cluster(devices=[jax.devices()[0]]))
    fabric.add_site("edge", devices=[0, 1])
    fabric.add_site("hub", devices=[0])
    fabric.connect("gpu", "edge", gbps=10.0, latency_ms=1.0)
    fabric.connect("gpu", "hub", gbps=1.0, latency_ms=5.0)
    fabric.connect("edge", "hub", gbps=1.0, latency_ms=5.0)
    fed = FederatedStore(fabric)
    sched = FairShareScheduler(fed=fed, reconcile_s=0.02,
                               preempt_grace_s=60.0)
    sched.create_tenant(TenantSpec("research", priority=0))
    sched.create_tenant(TenantSpec("chat", priority=5))
    surge = sched.create_tenant(TenantSpec("surge", priority=10,
                                           preemptible=False))

    horizon, windows, steps, ckpt_every = 120.0, 3, 12, 2
    spec = ScenarioSpec(
        name="e2e-chaos", horizon_s=horizon, windows=windows,
        slos={"chat": SLO(p99_ttft_s=60.0, p99_latency_s=120.0,
                          min_goodput=0.5)})
    serve = {"chat": ServePlan(
        shape=TrafficShape(
            name="chat",
            rate=DiurnalRate(base_rps=0.05, peak_rps=0.15,
                             period_s=horizon),
            zipf_a=1.7, max_prompt_len=16, gen_mu=1.3, gen_sigma=0.5,
            max_new_tokens=8, seed=5),
        manifest=ServeJob(name="chat", slots=2, prompt_len=16,
                          max_new_tokens=8,
                          lease_timeout=60.0).to_manifest())}
    train = {"research": TrainPlan(manifest=TrainJob(
        name="t", steps=steps, seq_len=32, global_batch=4,
        base_shape=(1, 1), max_data=1, ckpt_every=ckpt_every, log_every=4,
        rejoin_timeout_s=300.0, verbose=False, site="gpu", devices=1,
        min_devices=0,
        optimizer={"warmup_steps": 2, "decay_steps": 100}).to_manifest())}
    chaos = ChaosSchedule([
        ChaosEvent(at_s=50.0, kind="site-kill", site="edge"),
        ChaosEvent(at_s=50.0, kind="link-degrade", link=("gpu", "hub"),
                   gbps=0.05),
        ChaosEvent(at_s=100.0, kind="link-restore", link=("gpu", "hub")),
        ChaosEvent(at_s=110.0, kind="site-restore", site="edge"),
    ])

    # deterministic single preemption: the burst fires only once the
    # trainer has taken >= 3 steps, so one checkpoint window is at risk
    def fire_burst():
        while fabric.metrics.series("elastic/step").last < 3:
            time.sleep(0.005)
        surge.submit(JobSpec("burst", lambda ctx: time.sleep(0.3) or "ok",
                             devices_per_pod=1), site="gpu").wait(120)

    th = threading.Thread(target=fire_burst, daemon=True)
    with sched:
        th.start()
        result = run_scenario(sched, spec, serve=serve, train=train,
                              chaos=chaos)
        th.join(timeout=120)

    assert set(result.grades) == {"chat", "research"}
    g = result.grades["chat"]
    assert g.served + g.rejected == g.offered > 0
    assert set(g.verdicts) == {"p99_ttft", "p99_latency", "goodput"}
    applied = {(r["kind"], r.get("site") or tuple(r.get("link") or ()))
               for r in result.chaos_fired if r["applied"]}
    assert {("site-kill", "edge"), ("link-degrade", ("gpu", "hub")),
            ("link-restore", ("gpu", "hub")),
            ("site-restore", "edge")} <= applied
    # the preempted trainer resumed from its checkpoint and finished
    out = result.train_results["research"]
    assert sorted(out["loss_by_step"]) == list(range(steps))
    rep = out["report"]
    assert "preempted" in [s.outcome for s in rep.segments], \
        "gated burst must preempt the trainer"
    assert fabric.metrics.series("elastic/preemptions").total >= 1
    r = result.grades["research"]
    assert r.steps_lost <= ckpt_every, \
        f"lost {r.steps_lost} steps > ckpt_every={ckpt_every}"
