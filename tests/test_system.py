"""End-to-end behaviour tests for the paper's system: workflow resume,
pod-failure recovery, checkpoint fault tolerance, elastic rescale."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import Checkpointer
from repro.core.elastic import make_elastic_mesh, rescale_plan
from repro.core.metrics import StepReport, table_one
from repro.core.orchestrator import Cluster, JobSpec, PodState
from repro.core.workflow import Step, Workflow
from repro.data.objectstore import ObjectStore


@pytest.fixture()
def store(tmp_path):
    return ObjectStore(str(tmp_path / "store"))


@pytest.fixture()
def cluster():
    c = Cluster(devices=list(range(8)))   # 8 fake nodes
    c.create_namespace("default")
    return c


# ---------------------------------------------------------------- workflow

def test_workflow_runs_dag_in_order(cluster, store):
    order = []
    wf = Workflow("t", cluster=cluster, store=store)
    wf.add(Step("a", lambda ctx: order.append("a") or {"x": 1}))
    wf.add(Step("b", lambda ctx: order.append("b") or
                {"got": ctx.inputs["a"]["x"]}, deps=["a"]))
    out = wf.run()
    assert order == ["a", "b"]
    assert out["b"]["got"] == 1


def test_workflow_resume_skips_completed(cluster, store):
    calls = {"a": 0, "b": 0}

    def mk(name):
        def fn(ctx):
            calls[name] += 1
            if name == "b" and calls["b"] == 1:
                raise RuntimeError("first b fails")
            return {name: True}
        return fn

    wf = Workflow("t", cluster=cluster, store=store)
    wf.add(Step("a", mk("a")))
    wf.add(Step("b", mk("b"), deps=["a"]))
    with pytest.raises(RuntimeError):
        wf.run()
    # restart: a must be skipped (completed marker), b re-executed
    wf2 = Workflow("t", cluster=cluster, store=store)
    wf2.add(Step("a", mk("a")))
    wf2.add(Step("b", mk("b"), deps=["a"]))
    out = wf2.run()
    assert calls == {"a": 1, "b": 2}
    assert out["b"]["b"] is True


def test_workflow_isolated_step(cluster, store):
    wf = Workflow("t", cluster=cluster, store=store)
    wf.add(Step("a", lambda ctx: {"x": 41}))
    wf.add(Step("b", lambda ctx: {"y": ctx.inputs["a"]["x"] + 1}, deps=["a"]))
    wf.run(only="a")
    out = wf.run(only="b")   # PPoDS: develop/test b in isolation
    assert out["b"]["y"] == 42


def test_workflow_cycle_detection(cluster, store):
    wf = Workflow("t", cluster=cluster, store=store)
    wf.add(Step("a", lambda ctx: 1, deps=["b"]))
    wf.add(Step("b", lambda ctx: 1, deps=["a"]))
    with pytest.raises(ValueError, match="cycle"):
        wf.run()


def test_table_one_renders():
    md = table_one([StepReport("s1", pods=2, total_time_s=1.5),
                    StepReport("s2", devices=50,
                               data_processed_bytes=246 * 2**30)])
    assert "s1" in md and "246.0GB" in md and "# of Devices" in md


# ------------------------------------------------------------ orchestrator

def test_pod_failure_respawn(cluster):
    attempts = []

    def flaky(ctx):
        attempts.append(ctx.attempt)
        if ctx.attempt < 2:
            raise RuntimeError("pod crash")
        return "ok"

    job = cluster.submit("default", JobSpec("flaky", flaky, replicas=1,
                                            backoff_limit=3))
    cluster.wait(job, timeout=30)
    assert job.succeeded
    assert job.pods[0].restarts == 2
    assert attempts == [0, 1, 2]


def test_job_fails_after_backoff(cluster):
    job = cluster.submit("default", JobSpec(
        "dead", lambda ctx: 1 / 0, replicas=1, backoff_limit=1))
    with pytest.raises(RuntimeError, match="failed after backoff"):
        cluster.wait(job, timeout=30)


def test_namespace_quota(cluster):
    cluster.create_namespace("small", device_quota=2)
    with pytest.raises(RuntimeError, match="quota"):
        cluster.submit("small", JobSpec("big", lambda ctx: 1, replicas=1,
                                        devices_per_pod=4))


def test_namespace_isolation(cluster):
    cluster.create_namespace("a", device_quota=4)
    cluster.create_namespace("b", device_quota=4)
    ja = cluster.submit("a", JobSpec("ja", lambda ctx: len(ctx.devices),
                                     replicas=1, devices_per_pod=4))
    jb = cluster.submit("b", JobSpec("jb", lambda ctx: len(ctx.devices),
                                     replicas=1, devices_per_pod=4))
    cluster.wait(ja, timeout=30)
    cluster.wait(jb, timeout=30)
    assert ja.results() == [4] and jb.results() == [4]


def test_node_failure_shrinks_online_set(cluster):
    cluster.fail_node(cluster.devices[0])
    assert len(cluster.online_devices) == 7
    cluster.join_node(cluster.devices[0])
    assert len(cluster.online_devices) == 8


def test_quota_released_across_sequential_jobs(cluster):
    """The seed never released namespace quota: the 2nd identical job would
    hit 'quota exceeded' even though the 1st had long finished."""
    cluster.create_namespace("tight", device_quota=4)
    for _ in range(5):
        job = cluster.submit("tight", JobSpec(
            "j", lambda ctx: sorted(ctx.devices), replicas=2,
            devices_per_pod=2))
        cluster.wait(job, timeout=30)
        assert job.succeeded
    assert cluster.namespaces["tight"].used_devices == 0
    assert not cluster.leased


def test_no_device_double_lease_under_concurrent_pods(cluster):
    """Concurrently-live pods must hold disjoint devices (the seed handed
    avail[:n] to everyone)."""
    gate = threading.Event()
    started = threading.Barrier(4, timeout=10)

    def hold(ctx):
        started.wait()       # all 4 pods live at once
        gate.wait(timeout=10)
        return list(ctx.devices)

    jobs = [cluster.submit("default",
                           JobSpec(f"h{i}", hold, devices_per_pod=2))
            for i in range(4)]
    held = []
    for j in jobs:           # all pods are now holding their lease
        held.append(tuple(j.pods[0].ctx.devices))
    gate.set()
    for j in jobs:
        cluster.wait(j, timeout=30)
    flat = [d for devs in held for d in devs]
    assert len(flat) == len(set(flat)) == 8, f"double-leased: {held}"
    assert cluster.namespaces["default"].used_devices == 0


def test_fail_node_drains_pods_and_reconcile_recovers(cluster):
    """fail_node must drain the pods on the dead device (docstring contract)
    and reconcile must respawn them on freshly-allocated live devices."""
    release = threading.Event()
    seen_devices = []

    def fn(ctx):
        seen_devices.append(list(ctx.devices))
        if ctx.attempt == 0:
            release.wait(timeout=10)   # stay RUNNING until drained
        return sorted(ctx.devices)

    job = cluster.submit("default", JobSpec("train", fn, devices_per_pod=2,
                                            backoff_limit=3))
    pod = job.pods[0]
    victim = pod.ctx.devices[0]
    for _ in range(200):
        if pod.state == PodState.RUNNING:
            break
        time.sleep(0.01)
    cluster.fail_node(victim)
    assert pod.state == PodState.FAILED          # drained, not just offline
    assert "NodeFailure" in pod.error
    assert pod.ctx.should_stop()                 # cooperative kill signal
    release.set()
    cluster.wait(job, timeout=30)
    assert job.succeeded
    # the respawn re-allocated: the dead device is NOT reused
    assert victim not in job.pods[0].ctx.devices
    assert pod.restarts == 1
    assert cluster.namespaces["default"].used_devices == 0


def test_drained_pod_late_completion_stays_failed(cluster):
    """A drained pod that later finishes cooperatively keeps its FAILED
    state (the node IS gone) but its returned value is preserved — the
    elastic trainer reads the 'preempted at step k' marker from it."""
    release = threading.Event()

    def fn(ctx):
        release.wait(timeout=10)
        return "made-it-out"

    job = cluster.submit("default", JobSpec("x", fn, devices_per_pod=2,
                                            backoff_limit=0))
    pod = job.pods[0]
    for _ in range(200):
        if pod.state == PodState.RUNNING:
            break
        time.sleep(0.01)
    cluster.fail_node(pod.ctx.devices[0])
    assert pod.state == PodState.FAILED
    release.set()
    pod.thread.join(timeout=10)
    assert pod.state == PodState.FAILED          # not resurrected
    assert pod.result == "made-it-out"           # but the result survives
    assert cluster.namespaces["default"].used_devices == 0
    assert not cluster.leased


def test_wait_deadline_enforced_across_many_hung_pods():
    """Regression: the inner per-pod join loop used to check the deadline
    only once per outer pass — with many pods one pass costs
    len(pods) * reconcile_every seconds, so a hung pod overshot a short
    timeout by orders of magnitude.  The deadline now binds across the
    joins."""
    cluster = Cluster(devices=list(range(32)))
    cluster.create_namespace("default")
    release = threading.Event()

    def hung(ctx):                      # cooperative but never released
        release.wait(timeout=30)
        return "ok"

    job = cluster.submit("default", JobSpec("hung", hung, replicas=20,
                                            devices_per_pod=1))
    try:
        t0 = time.monotonic()
        with pytest.raises(TimeoutError):
            # old behaviour: one outer pass = 20 * 0.2s = 4s minimum
            cluster.wait(job, reconcile_every=0.2, timeout=0.5)
        elapsed = time.monotonic() - t0
        assert elapsed < 2.0, f"wait overshot its deadline: {elapsed:.2f}s"
    finally:
        release.set()                   # let the pod threads exit
        for pod in job.pods:
            pod.thread.join(timeout=10)


# -------------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip_and_gc(store):
    ck = Checkpointer(store, keep=2)
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    for step in (1, 2, 3):
        ck.save(step, tree, extra={"loss": 0.5})
    assert ck.all_steps() == [2, 3]          # GC keeps last 2
    ab = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    restored, meta = ck.restore_latest(ab)
    assert meta["step"] == 3 and meta["loss"] == 0.5
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_async(store):
    ck = Checkpointer(store, keep=1)
    ck.save_async(1, {"x": jnp.ones(3)})
    ck.wait()
    assert ck.latest_step() == 1


def test_checkpoint_atomic_commit(store):
    """A save without MANIFEST (simulated crash) is invisible to resume."""
    ck = Checkpointer(store, keep=5)
    ck.save(1, {"x": jnp.ones(3)})
    # simulate a crashed save: shard written, no manifest
    store.put_array("checkpoints/step_0000000002/x/shard0.npy", np.ones(3))
    assert ck.latest_step() == 1


def test_checkpoint_keep_semantics(store):
    """keep=0 keeps NOTHING (the seed treated it as GC-off); keep=None is
    the explicit GC-off spelling."""
    ck0 = Checkpointer(store, prefix="k0", keep=0)
    ck0.save(1, {"x": jnp.ones(2)})
    assert ck0.all_steps() == []
    ck_off = Checkpointer(store, prefix="koff", keep=None)
    for s in (1, 2, 3, 4, 5):
        ck_off.save(s, {"x": jnp.ones(2)})
    assert ck_off.all_steps() == [1, 2, 3, 4, 5]


def test_checkpoint_gc_deletes_manifest_first(store):
    """At any instant, a visible manifest's shards are all on disk: GC must
    delete MANIFEST.json before the shards (mirror of write-last commit)."""
    deleted = []
    orig = store.delete

    def spy(key):
        deleted.append(key)
        return orig(key)

    store.delete = spy
    ck = Checkpointer(store, keep=1)
    ck.save(1, {"x": jnp.ones(2)})
    ck.save(2, {"x": jnp.ones(2)})           # GCs step 1
    gc_keys = [k for k in deleted if "step_0000000001" in k]
    assert gc_keys and gc_keys[0].endswith("MANIFEST.json")


def test_checkpoint_gc_sweeps_orphaned_shards(store):
    """A GC pass that died between the manifest delete and the shard
    deletes must not leak those shards forever: the next pass sweeps
    manifest-less dirs older than the newest committed step — while a
    crashed/in-flight save at a NEWER step is left alone."""
    ck = Checkpointer(store, keep=1)
    ck.save(1, {"x": jnp.ones(2)})
    # simulate the dead GC: step 0's manifest gone, shards left behind
    store.put_array("checkpoints/step_0000000000/x/shard0.npy", np.ones(2))
    # simulate an in-flight save: shards first, no manifest yet — the
    # sequential writer always saves ABOVE the committed frontier
    store.put_array("checkpoints/step_0000000004/x/shard0.npy", np.ones(2))
    ck.save(3, {"x": jnp.ones(2)})
    assert not store.list("checkpoints/step_0000000000/")   # swept
    assert store.list("checkpoints/step_0000000004/")       # untouched


def test_checkpoint_gc_vs_concurrent_restore_latest(store):
    """A reader racing aggressive GC must always restore SOME committed
    step — never crash on a manifest whose shards were deleted."""
    ck = Checkpointer(store, keep=1)
    tree = {"w": jnp.arange(8, dtype=jnp.float32)}
    ab = {"w": jax.ShapeDtypeStruct((8,), jnp.float32)}
    ck.save(0, tree)
    stop = threading.Event()
    errors = []

    def reader():
        reader_ck = Checkpointer(store, keep=1)
        while not stop.is_set():
            try:
                restored, meta = reader_ck.restore_latest(ab)
                assert restored is not None
                np.testing.assert_array_equal(
                    np.asarray(restored["w"]), np.arange(8, dtype=np.float32))
            except Exception as e:     # pragma: no cover - failure capture
                errors.append(e)
                return

    threads = [threading.Thread(target=reader) for _ in range(2)]
    for t in threads:
        t.start()
    for s in range(1, 40):             # each save GCs the previous step
        ck.save(s, tree)
    stop.set()
    for t in threads:
        t.join(timeout=30)
    assert not errors, errors[:1]


# ------------------------------------------------------------------ elastic

def test_rescale_plan_shrinks_data_axis():
    plan = rescale_plan(("data", "model"), (4, 2), 6)
    assert plan.new_shape == (2, 2)
    assert plan.devices_idle == 2
    plan = rescale_plan(("pod", "data", "model"), (2, 4, 2), 16)
    assert plan.new_shape == (2, 4, 2)


def test_rescale_plan_insufficient_devices():
    with pytest.raises(RuntimeError, match="model replica"):
        rescale_plan(("data", "model"), (4, 4), 3)


def test_elastic_restore_preserves_values(store):
    ck = Checkpointer(store)
    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    ck.save(0, tree)
    plan = rescale_plan(("data", "model"), (1, 1), 1)
    mesh = make_elastic_mesh(plan, jax.devices()[:1])
    from jax.sharding import NamedSharding, PartitionSpec as P
    shd = {"w": NamedSharding(mesh, P("data", None))}
    ab = {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32)}
    restored = ck.restore(0, ab, shd)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))
