"""Workflow resume semantics under real restarts: a crashed-then-restarted
process (fresh Workflow/Cluster objects over the same store) skips
completed steps via their _COMPLETE markers, and ``only=`` runs one step
in isolation against its dependencies' STORED outputs (PPoDS §VI)."""
import json

import pytest

from repro.core.orchestrator import Cluster
from repro.core.workflow import Step, Workflow
from repro.data.objectstore import ObjectStore


def build(store, calls, crash_at=None):
    """A fresh 3-step chain, as a restarted process would construct it."""
    wf = Workflow("pipe", cluster=Cluster(devices=list(range(2))),
                  store=store)

    def mk(name, val):
        def fn(ctx):
            calls.append(name)
            if name == crash_at:
                raise RuntimeError(f"{name} crashed")
            return {"v": val, "saw": {d: ctx.inputs[d]["v"]
                                      for d in ctx.inputs}}
        return fn

    wf.add(Step("a", mk("a", 1)))
    wf.add(Step("b", mk("b", 2), deps=["a"]))
    wf.add(Step("c", mk("c", 3), deps=["b"]))
    return wf


def test_crash_restart_resumes_from_markers(tmp_path):
    store = ObjectStore(str(tmp_path))
    calls = []
    with pytest.raises(RuntimeError, match="b crashed"):
        build(store, calls, crash_at="b").run()
    assert calls == ["a", "b"]
    assert store.exists("workflows/pipe/a/_COMPLETE")
    assert not store.exists("workflows/pipe/b/_COMPLETE")
    # "restart": a brand-new workflow over the same store
    out = build(store, calls).run()
    assert calls == ["a", "b", "b", "c"]          # a skipped, b retried
    assert out["c"]["saw"] == {"b": 2}
    # a's output came from the store manifest, not a re-execution
    assert json.loads(store.get("workflows/pipe/a/output.json"))["v"] == 1


def test_resume_false_reruns_completed_steps(tmp_path):
    store = ObjectStore(str(tmp_path))
    calls = []
    build(store, calls).run()
    build(store, calls).run(resume=False)
    assert calls == ["a", "b", "c"] * 2


def test_only_runs_isolated_step_against_stored_outputs(tmp_path):
    store = ObjectStore(str(tmp_path))
    calls = []
    build(store, calls).run(only="a")
    build(store, calls).run(only="b")
    # each invocation executed exactly its own step; b's input was a's
    # stored output (the restarted-process case: nothing was in memory)
    assert calls == ["a", "b"]
    out = json.loads(store.get("workflows/pipe/b/output.json"))
    assert out["saw"] == {"a": 1}


def test_only_with_resume_false_reruns_completed_step(tmp_path):
    """The develop-one-step loop: ``only=step, resume=False`` re-executes
    the target (fresh code, same stored deps) even though it completed,
    while the OTHER steps still resolve from their stored outputs."""
    store = ObjectStore(str(tmp_path))
    calls = []
    build(store, calls).run()
    out = build(store, calls).run(only="b", resume=False)
    assert calls == ["a", "b", "c", "b"]
    assert out["b"]["saw"] == {"a": 1}            # dep from the store
    # and plain only= on a completed step is a cheap no-op (marker skip)
    build(store, calls).run(only="b")
    assert calls == ["a", "b", "c", "b"]


def test_missing_output_manifest_names_the_step(tmp_path):
    """A marker without its output manifest (partially-synced or
    hand-pruned store) fails with a clear error naming the step, not a
    KeyError from inside json.loads / the store."""
    store = ObjectStore(str(tmp_path))
    calls = []
    build(store, calls).run()
    store.delete("workflows/pipe/a/output.json")    # marker survives
    with pytest.raises(RuntimeError, match=r"step 'a'.*missing"):
        build(store, calls).run(only="b")
    with pytest.raises(RuntimeError, match=r"step 'a'.*missing"):
        build(store, calls).run()                   # resume path too
    # corrupt (unreadable) manifests are named the same way
    store.put("workflows/pipe/a/output.json", b"{not json")
    with pytest.raises(RuntimeError, match=r"step 'a'.*unreadable"):
        build(store, calls).run(only="b")


def test_cancel_emits_workflow_event_and_skips_remaining(tmp_path):
    """Cancelling mid-run reports ONE workflow-level ``cancelled`` event
    plus a ``skipped(reason=cancelled)`` step event for every step that
    will not run — including downstream steps never reached."""
    from repro.vcluster.monitor import EventBus
    store = ObjectStore(str(tmp_path))
    calls = []
    bus = EventBus()
    sub = bus.subscribe(maxlen=256)
    wf = build(store, calls)
    wf.bus = bus
    hits = {"n": 0}

    def stop_after_a():
        hits["n"] += 1
        return hits["n"] > 1            # a runs, then the signal trips

    out = wf.run(should_stop=stop_after_a)
    assert calls == ["a"] and "b" not in out
    evs = [(e.kind, e.data.get("step"), e.data.get("status"),
            e.data.get("reason"), e.data.get("remaining"))
           for e in sub.poll()]
    assert ("workflow", None, "cancelled", None, 2) in evs
    assert ("step", "b", "skipped", "cancelled", None) in evs
    assert ("step", "c", "skipped", "cancelled", None) in evs


def test_reset_clears_markers(tmp_path):
    store = ObjectStore(str(tmp_path))
    calls = []
    wf = build(store, calls)
    wf.run()
    wf.reset()
    assert not store.exists("workflows/pipe/a/_COMPLETE")
    build(store, calls).run()
    assert calls == ["a", "b", "c"] * 2
