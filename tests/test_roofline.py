"""Roofline machinery tests: the analytic FLOP accounting is cross-checked
against XLA's cost analysis on a small UNROLLED config (where XLA counts
everything), and the HLO collective parser against a hand-built module."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import ModelConfig, OptimizerConfig, ParallelConfig, \
    ShapeConfig
from repro.launch.mesh import single_device_mesh
from repro.roofline import flops as flops_mod
from repro.roofline import hlo as hlo_mod
from repro.runtime import steps as steps_mod


def test_analytic_flops_vs_xla_small_dense():
    """Unrolled tiny dense model: analytic fwd+bwd flops within 2x of XLA
    (XLA counts transcendental/elementwise we deliberately exclude)."""
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=256,
                      head_dim=16)
    par = ParallelConfig(scan_layers=False, remat=False)
    ocfg = OptimizerConfig()
    shape = ShapeConfig("t", 64, 2, "train")
    mesh = single_device_mesh()
    bundle = steps_mod.build_train(cfg, par, ocfg, mesh, shape)
    with mesh:
        compiled = bundle.lower().compile()
    xla = hlo_mod.xla_cost(compiled).get("flops", 0.0)
    # fwd * (1 fwd + 2 bwd) -- no remat here
    ours = flops_mod.forward_flops(cfg, shape, 1) * 3.0
    assert xla > 0
    assert 0.5 < ours / xla < 2.0, (ours, xla)


def test_model_flops_definition():
    cfg = registry.get_config("kimi-k2-1t-a32b")
    shape = ShapeConfig("t", 4096, 256, "train")
    acc = flops_mod.accounting(cfg, shape, 256)
    # ~1T total params, ~32B active
    assert 0.9e12 < acc.params < 1.3e12
    assert 25e9 < acc.active_params < 45e9
    assert acc.model_flops == pytest.approx(
        6.0 * acc.active_params * 256 * 4096)


def test_hlo_collective_parser():
    text = """
  %ag = f32[16,4096]{1,0} all-gather(%x), replica_groups=[16,16]<=[16,16]T(1,0), dimensions={0}
  %ar = bf16[8,128]{1,0} all-reduce(%y), replica_groups=[1,256]<=[256]
  %rs = f32[1,64]{1,0} reduce-scatter(%z), replica_groups=[16,16]<=[256]
  %a2a = bf16[4,32]{1,0} all-to-all(%w), replica_groups=[16,16]<=[256]
  %cp = f32[2,2]{1,0} collective-permute(%v), source_target_pairs={{0,1}}
"""
    got = hlo_mod.collective_bytes(text)
    assert got["all-gather"] == 16 * 4096 * 4 // 16
    assert got["all-reduce"] == 8 * 128 * 2
    assert got["reduce-scatter"] == 64 * 4 * 16
    assert got["all-to-all"] == 4 * 32 * 2
    assert got["collective-permute"] == 2 * 2 * 4
    assert got["total"] == sum(got[k] for k in
                               ("all-gather", "all-reduce", "reduce-scatter",
                                "all-to-all", "collective-permute"))
    # bf16 adjustment halves only the f32 entries
    f32_part = got["all-gather"] + got["reduce-scatter"] + \
        got["collective-permute"]
    assert got["total_bf16adj"] == got["total"] - f32_part // 2


def test_accounting_covers_all_archs():
    for arch in registry.ARCHS:
        cfg = registry.get_config(arch)
        for shape_name in ("train_4k", "prefill_32k", "decode_32k"):
            from repro.configs.base import SHAPES
            acc = flops_mod.accounting(cfg, SHAPES[shape_name], 256,
                                       registry.get_optimizer(arch))
            assert acc.step_flops_global > 0, (arch, shape_name)
            assert acc.model_flops > 0
            assert acc.params > 1e8
