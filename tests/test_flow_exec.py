"""Concurrent graph execution on the federated fabric (repro.flow).

The acceptance properties of the workflow-program subsystem: a
diamond-with-fan-out graph on a 3-site fabric runs independent branches
concurrently; killing the run mid-fan-out strands only the unfinished
branches (their markers never appear) and a re-run resumes EXACTLY the
missing ones — verified through step markers and EventBus events; plus
when:/repeat:/subworkflow/only= semantics end to end."""
import threading
import time

import pytest

from repro.core.workflow import Workflow
from repro.fabric import Fabric, FederatedStore, PlacementPlanner
from repro.flow import GraphRunner
from repro.vcluster.monitor import EventBus

WIDTH = 8


def mk_fabric(tmp_path, tag, devs=(2, 2, 2)):
    fabric = Fabric(time_scale=0.0)
    for i, n in enumerate(devs):
        fabric.add_site(f"s{i}", devices=list(range(n)),
                        store_root=str(tmp_path / f"{tag}-s{i}"))
    names = list(fabric.sites)
    for i, a in enumerate(names):
        for b in names[i + 1:]:
            fabric.connect(a, b, gbps=1.0, latency_ms=10.0)
    return fabric


def mk_wf(fed, bus=None):
    return Workflow("g", planner=PlacementPlanner(fed), bus=bus)


def plan(ctx, n=WIDTH):
    return {"chunks": [f"c{i}" for i in range(n)]}


def diamond(work_fn, left_fn=None):
    """plan -> (scatter seg, left) -> join: the diamond with fan-out."""
    return {"nodes": [
        {"step": "plan", "fn": plan},
        {"step": "seg", "deps": ["plan"], "fn": work_fn,
         "scatter": {"over": "plan.chunks"}},
        {"step": "left", "deps": ["plan"],
         "fn": left_fn or (lambda ctx: {"n": len(ctx.inputs["plan"]["chunks"])})},
        {"step": "join", "deps": ["seg", "left"],
         "fn": lambda ctx: {"segs": len(ctx.inputs["seg"]),
                            "left": ctx.inputs["left"]["n"]}},
    ]}


def test_fanout_runs_branches_concurrently(tmp_path):
    """With a worker pool, the 8-branch scatter overlaps: peak
    in-flight > 1 and makespan well under the serial sum."""
    in_flight = {"now": 0, "peak": 0}
    lock = threading.Lock()

    def work(ctx):
        with lock:
            in_flight["now"] += 1
            in_flight["peak"] = max(in_flight["peak"], in_flight["now"])
        time.sleep(0.05)
        with lock:
            in_flight["now"] -= 1
        return {"i": ctx.inputs["index"]}

    fed = FederatedStore(mk_fabric(tmp_path, "conc"))
    bus = EventBus()
    sub = bus.subscribe(maxlen=2048)
    t0 = time.perf_counter()
    out = GraphRunner(mk_wf(fed, bus), diamond(work), max_workers=8).run()
    makespan = time.perf_counter() - t0
    assert out["join"] == {"segs": WIDTH, "left": WIDTH}
    assert [o["i"] for o in out["seg"]] == list(range(WIDTH))
    assert in_flight["peak"] > 1, "branches never overlapped"
    assert makespan < 0.05 * WIDTH, f"no speedup: {makespan:.2f}s"
    evs = sub.poll()
    done = [e for e in evs if e.kind == "branch"
            and e.data.get("status") == "done" and e.data["of"] == "seg"]
    assert sorted(e.data["branch"] for e in done) == list(range(WIDTH))
    assert {e.data["site"] for e in done} == {"s0", "s1", "s2"}, \
        "branches should spread across the 3 sites"
    scatter = [e for e in evs if e.data.get("status") == "scatter"]
    assert scatter and scatter[0].data["width"] == WIDTH


def test_kill_mid_fanout_resumes_only_missing_branches(tmp_path):
    """The acceptance regression: cancel once 3 branches have started;
    finished branches keep their markers, queued ones are revoked, and
    the re-run executes exactly the complement (verified by markers AND
    by the branch skipped/done events)."""
    fed = FederatedStore(mk_fabric(tmp_path, "kill"))
    started = []

    def work(ctx):
        started.append(ctx.inputs["index"])
        time.sleep(0.04)
        return {"i": ctx.inputs["index"]}

    bus = EventBus()
    sub = bus.subscribe(maxlen=2048)
    wf = mk_wf(fed, bus)
    runner = GraphRunner(wf, diamond(work), max_workers=2)
    runner.run(should_stop=lambda: len(started) >= 3)
    evs = sub.poll()
    assert any(e.kind == "workflow" and e.data["status"] == "cancelled"
               for e in evs), "no workflow-level cancelled event"

    ctrl = wf._ctrl()
    done_first = {i for i in range(WIDTH)
                  if ctrl.exists(f"workflows/g/seg#{i}/_COMPLETE")}
    assert 0 < len(done_first) < WIDTH, sorted(done_first)
    assert not ctrl.exists("workflows/g/seg/_COMPLETE"), \
        "incomplete fan-out must not gather"
    ev_done = {e.data["branch"] for e in evs if e.kind == "branch"
               and e.data.get("status") == "done"}
    assert ev_done == done_first     # events agree with the markers

    # --- re-run (fresh objects over the same fed store) ---
    ran = []

    def work2(ctx):
        ran.append(ctx.inputs["index"])
        return {"i": ctx.inputs["index"]}

    bus2 = EventBus()
    sub2 = bus2.subscribe(maxlen=2048)
    out = GraphRunner(mk_wf(fed, bus2), diamond(work2),
                      max_workers=4).run()
    assert out["join"]["segs"] == WIDTH
    assert sorted(ran) == sorted(set(range(WIDTH)) - done_first), \
        "resume must run ONLY the missing branches"
    evs2 = sub2.poll()
    skipped = {e.data["branch"] for e in evs2 if e.kind == "branch"
               and e.data.get("status") == "skipped"}
    assert skipped == done_first

    # a third run marker-skips the whole gathered fan-out wholesale
    ran.clear()
    out3 = GraphRunner(mk_wf(fed), diamond(work2)).run()
    assert out3["join"]["segs"] == WIDTH and ran == []


def test_failed_branch_fails_run_but_keeps_finished_markers(tmp_path):
    fed = FederatedStore(mk_fabric(tmp_path, "fail"))

    def work(ctx):
        if ctx.inputs["index"] == 5:
            raise ValueError("branch 5 exploded")
        return {"i": ctx.inputs["index"]}

    with pytest.raises(ValueError, match="branch 5"):
        GraphRunner(mk_wf(fed), diamond(work), max_workers=3).run()
    ctrl = fed
    assert not ctrl.exists("workflows/g/seg#5/_COMPLETE")
    done = [i for i in range(WIDTH)
            if ctrl.exists(f"workflows/g/seg#{i}/_COMPLETE")]
    assert done, "finished branches must keep their markers"

    def fixed(ctx):
        return {"i": ctx.inputs["index"]}

    out = GraphRunner(mk_wf(fed), diamond(fixed)).run()
    assert out["join"]["segs"] == WIDTH


def test_when_false_skips_node_and_cascades(tmp_path):
    fed = FederatedStore(mk_fabric(tmp_path, "when"))
    graph = {"nodes": [
        {"step": "plan", "fn": plan},
        {"step": "gated", "deps": ["plan"],
         "when": f"len(plan.chunks) > {WIDTH}",
         "fn": lambda ctx: {"ran": True}},
        {"step": "after", "deps": ["gated"],
         "fn": lambda ctx: {"ran": True}},
        {"step": "always", "deps": ["plan"],
         "when": f"len(plan.chunks) == {WIDTH}",
         "fn": lambda ctx: {"ran": True}},
    ]}
    bus = EventBus()
    sub = bus.subscribe(maxlen=256)
    out = GraphRunner(mk_wf(fed, bus), graph).run()
    assert out["always"]["ran"] and "gated" not in out and "after" not in out
    reasons = {e.data["step"]: e.data.get("reason") for e in sub.poll()
               if e.data.get("status") == "skipped"}
    assert reasons == {"gated": "when", "after": "when-upstream"}
    # when-skips write no markers: conditions re-evaluate on resume
    assert not fed.exists("workflows/g/gated/_COMPLETE")


def test_repeat_until_iterates_with_markers_and_resumes(tmp_path):
    fed = FederatedStore(mk_fabric(tmp_path, "loop"))
    runs = []

    def bump(ctx):
        prev = ctx.inputs["prev"] or {"v": 0}
        runs.append(ctx.inputs["i"])
        return {"v": prev["v"] + 1}

    graph = {"nodes": [
        {"step": "init", "fn": lambda ctx: {"v": 0}},
        {"step": "tune", "deps": ["init"], "fn": bump,
         "repeat": {"until": "output.v >= 3", "max": 10}},
        {"step": "use", "deps": ["tune"],
         "fn": lambda ctx: {"got": ctx.inputs["tune"]["v"]}},
    ]}
    out = GraphRunner(mk_wf(fed), graph).run()
    assert out["use"]["got"] == 3 and runs == [0, 1, 2]
    assert fed.exists("workflows/g/tune#2/_COMPLETE")
    assert not fed.exists("workflows/g/tune#3/_COMPLETE")
    # resume: the loop's own marker skips it wholesale
    out2 = GraphRunner(mk_wf(fed), graph).run()
    assert out2["use"]["got"] == 3 and runs == [0, 1, 2]


def test_nested_subworkflow_flattens_and_collects(tmp_path):
    fed = FederatedStore(mk_fabric(tmp_path, "sub"))
    graph = {"nodes": [
        {"step": "a", "fn": lambda ctx: {"x": 1}},
        {"step": "sub", "deps": ["a"], "graph": {"nodes": [
            {"step": "b",
             "fn": lambda ctx: {"y": ctx.inputs["a"]["x"] + 1}},
            {"step": "c", "deps": ["b"],
             "fn": lambda ctx: {"z": ctx.inputs["b"]["y"] * 10}},
        ]}},
        {"step": "d", "deps": ["sub"],
         "fn": lambda ctx: {"f": ctx.inputs["sub"]["c"]["z"]}},
    ]}
    out = GraphRunner(mk_wf(fed), graph).run()
    assert out["d"]["f"] == 20
    assert out["sub"] == {"b": {"y": 2}, "c": {"z": 20}}
    assert fed.exists("workflows/g/sub.b/_COMPLETE")
    # only= reaches INTO the flattened subworkflow (deps from the store)
    out2 = GraphRunner(mk_wf(fed), graph).run(only="sub.c")
    assert out2["sub.c"]["z"] == 20


def test_only_missing_dep_raises_clear_error(tmp_path):
    fed = FederatedStore(mk_fabric(tmp_path, "only"))
    graph = {"nodes": [
        {"step": "a", "fn": lambda ctx: {"x": 1}},
        {"step": "b", "deps": ["a"],
         "fn": lambda ctx: ctx.inputs["a"]},
    ]}
    with pytest.raises(RuntimeError, match=r"depends on 'a'"):
        GraphRunner(mk_wf(fed), graph).run(only="b")
