"""Paged KV pool, prefix reuse, replicas — and the serving-loop fixes.

Host-side policy (BlockPool refcounts/eviction, router/autoscaler,
scheduler accounting) is tested with fake clocks and fake engines — no
devices.  The paged decode path is pinned against the slotted baseline
bit for bit on the smoke config, and the pool-pressure preemption path
runs through the real engine.
"""
import math
import time

import numpy as np
import pytest

from repro.core.metrics import Registry
from repro.core.queue import WorkQueue
from repro.serving.pool import BlockPool
from repro.serving.report import GAUGES
from repro.serving.router import Autoscaler, ReplicaSet, serve_replicated
from repro.serving.scheduler import ContinuousScheduler


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def mk_requests(gens, prompt=(5, 6, 7)):
    return [{"id": i, "prompt": list(prompt), "max_new_tokens": g}
            for i, g in enumerate(gens)]


# -------------------------------------------------------------- block pool

def test_pool_alloc_release_refcount():
    pool = BlockPool(6, 4)              # block 0 reserved: 5 usable
    assert pool.free_blocks == 5 and pool.in_use == 0
    blocks = pool.alloc(3)
    assert len(blocks) == 3 and 0 not in blocks
    assert all(pool.ref(b) == 1 for b in blocks)
    assert pool.in_use == 3 and pool.free_blocks == 2
    pool.release(blocks)
    assert pool.in_use == 0 and pool.free_blocks == 5
    with pytest.raises(ValueError, match="refcount"):
        pool.release([blocks[0]])       # double free


def test_pool_exhaustion_allocates_nothing():
    pool = BlockPool(4, 4)
    assert pool.alloc(4) is None        # only 3 usable: all-or-nothing
    assert pool.in_use == 0
    got = pool.alloc(3)
    assert pool.alloc(1) is None
    pool.release(got[:1])
    assert pool.alloc(1) is not None


def test_pool_prefix_match_is_content_exact():
    pool = BlockPool(8, 2)
    prompt = [1, 2, 3, 4, 5, 6]
    blocks = pool.alloc(3)
    assert pool.cache_prefix(prompt, blocks) == 3
    pool.release(blocks)                # cached: stay resident at ref 0
    assert pool.cached_blocks == 3 and pool.in_use == 0

    hit = pool.match(prompt, max_blocks=2)
    assert hit == blocks[:2]            # capped below the full prompt
    assert all(pool.ref(b) == 1 for b in hit)
    # same length, different content: chain key misses at block 0
    assert pool.match([9, 9, 3, 4, 5, 6], max_blocks=2) == []
    # shared first block only: chain stops after one
    assert pool.match([1, 2, 9, 9], max_blocks=2) == blocks[:1]

    reg = pool.metrics.summary()
    assert reg["serve/prefix_hits"]["total"] == 3
    assert reg["serve/prefix_misses"]["total"] >= 2


def test_pool_lru_eviction_only_under_pressure():
    pool = BlockPool(4, 2)              # 3 usable
    a = pool.alloc(2)
    pool.cache_prefix([1, 2, 3, 4], a)
    pool.release(a)                     # both cached at ref 0
    b = pool.alloc(1)                   # free list still has one: no evict
    assert pool.cached_blocks == 2
    c = pool.alloc(2)                   # pressure: evicts LRU cached pair
    assert c is not None and pool.cached_blocks == 0
    assert pool.match([1, 2, 3, 4]) == []
    pool.release(b + c)


def test_pool_cached_block_with_live_ref_is_not_evictable():
    pool = BlockPool(3, 2)              # 2 usable
    a = pool.alloc(2)
    pool.cache_prefix([1, 2, 3, 4], a)
    pool.release(a[1:])                 # a[0] still referenced
    assert pool.alloc(2) is None        # only a[1] is reclaimable
    got = pool.alloc(1)
    assert got == [a[1]]
    pool.release(got + a[:1])


# --------------------------------------------------- serving-loop bug fixes

def test_ttft_measured_from_enqueue_not_admit():
    """With one slot, the second request's TTFT must include its queue
    wait; service TTFT (admit -> first token) stays small for both."""
    clock = FakeClock()
    reg = Registry()
    q = WorkQueue(mk_requests([3, 3]), clock=clock)
    sched = ContinuousScheduler(q, 1, registry=reg, clock=clock)
    while not sched.finished():
        for slot in sched.admit():
            clock.advance(0.5)          # prefill cost
            sched.start(slot, 100, 8)
        if sched.active():
            clock.advance(1.0)          # fused decode step
            sched.observe([101])
    ttft = [v for _, v in reg.series(GAUGES.TTFT_S).points]
    service = [v for _, v in reg.series(GAUGES.SERVICE_TTFT_S).points]
    assert ttft[0] == pytest.approx(0.5)         # admitted instantly
    assert service[0] == pytest.approx(0.5)
    # request 1 waited for request 0's 2 decode steps before admission
    assert ttft[1] == pytest.approx(2.5 + 0.5)
    assert service[1] == pytest.approx(0.5)
    assert ttft[1] > service[1]


def test_stale_ack_tokens_are_not_useful_throughput():
    clock = FakeClock()
    reg = Registry()
    q = WorkQueue(mk_requests([3]), lease_timeout=10.0, clock=clock)
    sched = ContinuousScheduler(q, 1, registry=reg, clock=clock)
    [slot] = sched.admit()
    sched.start(slot, 100, 8)
    clock.advance(11.0)                 # lease expires mid-decode
    tid, _ = q.lease("thief")
    assert q.ack(tid, "thief")          # the reclaimer finishes first
    sched.observe([101])
    done = sched.observe([102])         # original completes -> stale ack
    assert done and done[0][1] == [100, 101, 102]
    s = reg.summary()
    assert s["serve/stale_ack"]["total"] == 1
    assert s["serve/stale_tokens"]["total"] == 3
    assert "serve/tokens_generated" not in s     # nothing counted useful
    assert sched.useful_tokens == 0 and sched.stale_tokens == 3


def test_release_all_nacks_inflight_leases():
    clock = FakeClock()
    q = WorkQueue(mk_requests([5, 5, 5]), lease_timeout=1000.0, clock=clock)
    sched = ContinuousScheduler(q, 2, clock=clock)
    for slot in sched.admit():
        sched.start(slot, 100, 8)
    assert q.pending == 1 and q.leased == 2
    assert sched.release_all() == 2
    # nacked, not abandoned: pending again NOW, not one timeout later
    assert q.pending == 3 and q.leased == 0
    assert sched.occupancy == 0


def test_queue_snapshot_restore_preserves_fifo_order():
    clock = FakeClock()
    q = WorkQueue([], lease_timeout=5.0, clock=clock)
    for name in "abcd":
        q.put({"id": name})
    ta, _ = q.lease("w")                # a in flight at snapshot time
    tb, _ = q.lease("w")
    assert q.nack(tb, "w")              # b requeued behind c, d
    snap = q.snapshot()

    q2 = WorkQueue.restore(snap, clock=clock)
    order = []
    while True:
        got = q2.lease("w2")
        if got is None:
            break
        order.append(got[1]["id"])
    # snapshotted pending order first (c, d, b), then the task that was
    # leased at snapshot time (a) — never re-sorted into id order
    assert order == ["c", "d", "b", "a"]

    legacy = dict(snap)
    del legacy["pending"]               # old snapshot: degrades to id order
    q3 = WorkQueue.restore(legacy, clock=clock)
    assert [q3.lease("w")[1]["id"] for _ in range(4)] == list("abcd")


def test_queue_put_preserves_original_enqueue_time():
    clock = FakeClock(t=7.0)
    q = WorkQueue(clock=clock)
    tid = q.put({"id": 0}, enqueued_at=2.0)
    assert q.enqueued_at(tid) == 2.0
    assert q.enqueued_at(q.put({"id": 1})) == 7.0


# ------------------------------------------------------ router / autoscaler

class FakeEngine:
    """Queue-draining stand-in for ServingEngine: acks instantly, nacks
    in-flight work on stop, records the fleet-shared serve gauges."""

    def __init__(self, registry, delay=0.0):
        self.metrics = registry
        self.delay = delay

    def run(self, queue, *, worker="server", should_stop=None,
            exit_on_drain=False, **_):
        results = {}
        while not (should_stop is not None and should_stop()):
            got = queue.lease(worker)
            if got is None:
                if exit_on_drain and queue.drained():
                    break
                time.sleep(0.001)
                continue
            tid, item = got
            if self.delay:
                time.sleep(self.delay)
            if should_stop is not None and should_stop():
                queue.nack(tid, worker)
                break
            queue.ack(tid, worker)
            n = int(item.get("max_new_tokens", 1))
            results[item["id"]] = [7] * n
            self.metrics.inc(GAUGES.COMPLETED)
            self.metrics.inc(GAUGES.TOKENS, n)
        return results, self.metrics


class IdleEngine:
    """Never consumes; exists so routing/draining can be observed."""

    def __init__(self, registry):
        self.metrics = registry

    def run(self, queue, *, worker="server", should_stop=None, **_):
        while not (should_stop is not None and should_stop()):
            time.sleep(0.001)
        return {}, self.metrics


def test_serve_replicated_scales_up_and_serves_everything():
    reg = Registry()
    reqs = mk_requests([2] * 24)
    results, metrics, events = serve_replicated(
        lambda name, r: FakeEngine(r, delay=0.01), reqs,
        min_replicas=1, max_replicas=3, target_backlog=2.0,
        registry=reg, reconcile_interval=0.005, timeout_s=30.0)
    assert sorted(results) == list(range(24))
    assert all(v == [7, 7] for v in results.values())
    reasons = [e[3] for e in events]
    assert reasons[0] == "startup" and "shutdown" in reasons
    # the 24-deep backlog over target 2 forced a scale-up past 1 replica
    assert metrics.series(GAUGES.REPLICAS).max >= 2
    assert metrics.series(GAUGES.SCALE_EVENTS).total == len(events)
    assert metrics.series(GAUGES.TOK_S).last > 0


def test_router_session_affinity_and_least_loaded():
    rset = ReplicaSet(lambda name, r: IdleEngine(r))
    rset.scale_to(2)
    a1 = rset.submit({"id": 0, "prompt": [1], "session": "alice"})
    a2 = rset.submit({"id": 1, "prompt": [1], "session": "alice"})
    assert a1 == a2                     # pinned: the replica's prefix
    b = rset.submit({"id": 2, "prompt": [1], "session": "bob"})
    assert b != a1                      # least-loaded breaks the tie
    rset.stop_all()


def test_scale_down_drains_queue_with_enqueue_time_preserved():
    clock = FakeClock(t=5.0)
    rset = ReplicaSet(lambda name, r: IdleEngine(r), clock=clock)
    rset.scale_to(2)
    for i in range(4):
        rset.submit({"id": i, "prompt": [1]})
    clock.advance(40.0)                 # well past any lease window
    rset.scale_to(1, reason="drain-test")
    [survivor] = rset._replicas
    assert survivor.queue.pending == 4  # nothing lost in the retirement
    order = []
    while True:
        got = survivor.queue.lease("w")
        if got is None:
            break
        tid, item = got
        # migrated requests keep charging TTFT from the FIRST enqueue
        assert survivor.queue.enqueued_at(tid) == 5.0
        order.append(item["id"])
    assert sorted(order) == [0, 1, 2, 3]
    rset.stop_all()


def test_autoscaler_recommend_clamps_and_slo_bump():
    class StubSet:
        def __init__(self):
            self.metrics = Registry()
            self.backlog = 0
            self.n = 1

        def total_backlog(self):
            return self.backlog

        def observed(self):
            return self.n

    stub = StubSet()
    sc = Autoscaler(stub, min_replicas=1, max_replicas=4,
                    target_backlog=4.0, ttft_slo_s=0.5)
    assert sc.recommend() == 1          # empty backlog, SLO series empty
    stub.backlog = 9
    assert sc.recommend() == math.ceil(9 / 4.0)
    stub.backlog = 100
    assert sc.recommend() == 4          # max clamp
    stub.backlog = 0
    stub.metrics.gauge(GAUGES.SERVICE_TTFT_S, 2.0)
    assert sc.recommend() == stub.n + 1     # latency breach: +1
    with pytest.raises(ValueError, match="min_replicas"):
        Autoscaler(stub, min_replicas=3, max_replicas=2)


def test_replicaset_capacity_gates_scale_up():
    granted = []

    def capacity(want):
        granted.append(want)
        return min(want, 2)             # the fair share caps the fleet

    rset = ReplicaSet(lambda name, r: IdleEngine(r), capacity=capacity)
    rset.scale_to(4)
    assert rset.observed() == 2
    rset.scale_to(0)
    assert granted == [4]               # scale-down never asks


def test_resize_claim_respects_fair_share():
    from repro.fabric import Fabric
    from repro.vcluster import FairShareScheduler, TenantSpec

    fabric = Fabric()
    fabric.add_site("s0", devices=list(range(4)))
    sched = FairShareScheduler(fabric)
    a = sched.create_tenant(TenantSpec("a", site_quota=4))
    sched.create_tenant(TenantSpec("b", site_quota=4))
    ca = a.claim("s0", 1, min_devices=1)
    cb = sched.claim("b", "s0", want=2)
    assert ca.granted == 1 and cb.granted == 2
    # growth clamps at what b's reservation leaves free
    assert sched.resize_claim(ca, 4) == 2
    sched.release_claim(cb)
    assert sched.resize_claim(ca, 4) == 4       # b's share returned
    assert sched.resize_claim(ca, 1) == 1       # shrink always succeeds
    ca.release()
    with pytest.raises(ValueError, match="released"):
        sched.resize_claim(ca, 2)


# ------------------------------------------------- paged engine (smoke cfg)

@pytest.fixture(scope="module")
def serve_setup():
    from repro.configs import registry as cfg_registry
    from repro.launch.mesh import single_device_mesh

    return dict(cfg=cfg_registry.get_smoke("phi4-mini-3.8b"),
                par=cfg_registry.get_parallel("phi4-mini-3.8b"),
                mesh=single_device_mesh())


def mk_engine(s, **kw):
    from repro.serving import ServingEngine
    kw.setdefault("num_slots", 2)
    kw.setdefault("prompt_len", 8)
    kw.setdefault("max_new_tokens", 8)
    return ServingEngine(s["cfg"], s["par"], s["mesh"], **kw)


def prompt_requests(cfg, gens, *, seed=0, shared_prefix=0):
    rng = np.random.RandomState(seed)
    head = rng.randint(1, cfg.vocab_size, shared_prefix).tolist()
    return [{"id": i,
             "prompt": head + rng.randint(1, cfg.vocab_size,
                                          8 - shared_prefix).tolist(),
             "max_new_tokens": g}
            for i, g in enumerate(gens)]


def test_paged_decode_bit_identical_to_slotted(serve_setup):
    """The acceptance pin: gather/scatter block addressing must produce
    the SAME tokens as the contiguous slotted cache on an identical
    trace — the null block's garbage is exactly masked out."""
    s = serve_setup
    gens = [8, 3, 5, 8, 2]
    e_slot = mk_engine(s, paged=False, seed=0)
    e_page = mk_engine(s, paged=True, block_size=4, prefix_cache=False,
                       seed=0, params=e_slot.params)
    assert not e_slot.paged and e_page.paged
    reqs = prompt_requests(s["cfg"], gens)
    r_slot, _ = e_slot.run(WorkQueue(reqs))
    r_page, m = e_page.run(WorkQueue(reqs))
    assert r_page == r_slot
    assert [len(r_page[i]) for i in range(5)] == gens
    assert m.summary()["serve/tokens_generated"]["total"] == sum(gens)


def test_paged_prefix_reuse_hits_and_refcounts(serve_setup):
    """Identical prompts through one slot: the first request prefills and
    caches its prompt blocks, every later one retains them (hit) and
    replays only the uncached suffix through the decode step."""
    s = serve_setup
    engine = mk_engine(s, num_slots=1, paged=True, block_size=4,
                       prefix_cache=True, seed=0)
    reqs = prompt_requests(s["cfg"], [4, 4, 4], seed=3)
    same = reqs[0]["prompt"]
    for r in reqs:
        r["prompt"] = list(same)
    results, metrics = engine.run(WorkQueue(reqs))
    assert [len(results[i]) for i in range(3)] == [4, 4, 4]
    sm = metrics.summary()
    # nb_prompt=2, shareable capped at 1 block: requests 1 and 2 hit it
    assert sm["serve/prefix_hits"]["total"] == 2
    assert sm["serve/prefix_bytes_saved"]["total"] > 0
    # all slots drained: every block released back (cached ones at ref 0)
    assert engine.block_pool.in_use == 0
    assert engine.block_pool.cached_blocks >= 1


def test_paged_pool_pressure_preempts_youngest_and_recovers(serve_setup):
    """A pool too small for two full generations: the youngest slot is
    nacked back to the queue when the elder needs its next block, and
    every request still completes exactly."""
    s = serve_setup
    # nb_prompt=2, nb_total=4: each request needs up to 5 blocks; 6
    # usable blocks cannot hold two full generations at once
    engine = mk_engine(s, paged=True, block_size=4, prefix_cache=False,
                       pool_blocks=7, seed=0)
    reqs = prompt_requests(s["cfg"], [8, 8, 8], seed=1)
    queue = WorkQueue(reqs, max_attempts=100)
    results, metrics = engine.run(queue)
    assert sorted(results) == [0, 1, 2]
    assert all(len(v) == 8 for v in results.values())
    assert metrics.summary()["serve/preempted"]["total"] >= 1
    assert queue.drained() and not queue.dead
    assert engine.block_pool.in_use == 0


def test_engine_stop_nacks_within_one_step_not_one_timeout(serve_setup):
    """Cooperative stop with a huge visibility timeout: the in-flight
    requests must be re-servable immediately (nack), not after the
    lease expires — the preempted-replica acceptance bound."""
    s = serve_setup
    reqs = [{"id": i, "prompt": [1 + i] * 4, "max_new_tokens": 3}
            for i in range(4)]
    queue = WorkQueue(reqs, lease_timeout=1000.0)
    engine = mk_engine(s, prompt_len=4, max_new_tokens=3)
    calls = {"n": 0}

    def stop_after_two():
        calls["n"] += 1
        return calls["n"] > 2

    results, metrics = engine.run(queue, should_stop=stop_after_two)
    assert len(results) < 4
    assert queue.leased == 0            # nacked, not left to expire
    assert queue.pending == 4 - queue.completed
    # a replacement engine re-serves them NOW — no sleep, no timeout wait
    engine2 = mk_engine(s, prompt_len=4, max_new_tokens=3,
                        params=engine.params)
    results2, _ = engine2.run(queue)
    done = dict(results)
    done.update(results2)
    assert sorted(done) == [0, 1, 2, 3]
    assert queue.drained()


def test_engine_timing_rides_the_injected_clock(serve_setup):
    """All engine timing flows through self.clock: under a never-
    advancing fake clock every wall/TTFT stat is exactly zero even
    though real seconds elapsed."""
    s = serve_setup
    clock = FakeClock()
    engine = mk_engine(s, prompt_len=4, max_new_tokens=2, clock=clock)
    results, metrics = engine.run(
        WorkQueue([{"id": 0, "prompt": [1, 2], "max_new_tokens": 2}],
                  clock=clock))
    assert len(results[0]) == 2
    sm = metrics.summary()
    assert sm["serve/wall_s"]["last"] == 0.0
    assert sm["serve/ttft_s"]["last"] == 0.0
    assert sm["serve/request_latency_s"]["last"] == 0.0
    assert sm["serve/prefill_s"]["max"] == 0.0
