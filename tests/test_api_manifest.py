"""Manifest round-trip + validation properties for repro.api resources.

The contract: every workload spec survives ``to_manifest() ->
json -> from_manifest()`` unchanged (losslessness), and malformed
manifests — unknown kind, missing required field, wrong type, unknown
field — fail validation with the offending field NAMED."""
import dataclasses
import json

import pytest

from repro.api import (BatchJob, ManifestError, ServeJob, TrainJob,
                       WorkflowRun, from_json, from_manifest,
                       resolve_entrypoint)

try:
    from hypothesis import given, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # optional dev dependency
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    names = st.text(alphabet="abcdefghijklmnopqrstuvwxyz0123456789-",
                    min_size=1, max_size=12)
    opt_names = st.none() | names
    small_floats = st.floats(min_value=0.5, max_value=600.0,
                             allow_nan=False, allow_infinity=False)
    json_dicts = st.none() | st.dictionaries(
        names, st.integers(0, 99) | small_floats | st.booleans() | names,
        max_size=3)

    train_jobs = st.builds(
        TrainJob,
        name=names, steps=st.integers(1, 500),
        arch=st.sampled_from(["phi4-mini-3.8b", "gemma2-9b"]),
        smoke=st.booleans(), seq_len=st.integers(1, 256),
        global_batch=st.integers(1, 64),
        base_shape=st.tuples(st.integers(1, 8), st.integers(1, 8)),
        max_data=st.none() | st.integers(1, 8),
        ckpt_dir=st.sampled_from(["", "/tmp/ckpt"]),
        ckpt_every=st.integers(0, 10), keep=st.none() | st.integers(0, 5),
        log_every=st.integers(0, 20), fail_at=st.integers(-1, 99),
        seed=st.integers(0, 9), data_seed=st.integers(0, 9),
        rejoin_timeout_s=small_floats, verbose=st.booleans(),
        namespace=opt_names, config=json_dicts, optimizer=json_dicts,
        site=opt_names, devices=st.none() | st.integers(1, 8),
        min_devices=st.none() | st.integers(0, 4))

    serve_jobs = st.builds(
        ServeJob,
        name=names, arch=st.just("phi4-mini-3.8b"), smoke=st.booleans(),
        n_requests=st.integers(0, 32), prompt_len=st.integers(1, 64),
        max_new_tokens=st.integers(1, 32), slots=st.integers(1, 8),
        seed=st.integers(0, 9),
        gen_lens=st.none() | st.tuples(st.integers(1, 9),
                                       st.integers(1, 9)),
        lease_timeout=small_floats, warmup=st.booleans(),
        requests=st.none() | st.lists(
            st.fixed_dictionaries(
                {"id": st.integers(0, 99),
                 "prompt": st.lists(st.integers(1, 50), min_size=1,
                                    max_size=4)}),
            max_size=3),
        site=opt_names,
        paged=st.none() | st.booleans(), block_size=st.integers(1, 16),
        pool_blocks=st.none() | st.integers(2, 64),
        prefix_cache=st.booleans(),
        max_replicas=st.integers(1, 4),
        target_backlog=small_floats,
        ttft_slo_s=st.none() | small_floats)

    batch_jobs = st.builds(
        BatchJob,
        name=names, replicas=st.integers(1, 8),
        devices_per_pod=st.integers(0, 4),
        backoff_limit=st.integers(0, 5),
        priority=st.none() | st.integers(-5, 5), namespace=opt_names,
        site=opt_names, entrypoint=st.none() | st.just("builtins:repr"),
        params=json_dicts)

    workflow_runs = st.builds(
        WorkflowRun,
        name=names, namespace=opt_names, resume=st.booleans(),
        only=opt_names,
        entrypoint=st.none() |
        st.just("repro.apps.connect.pipeline:add_connect_steps"),
        params=json_dicts)

    all_specs = train_jobs | serve_jobs | batch_jobs | workflow_runs

    @given(all_specs)
    def test_manifest_round_trip_lossless(spec):
        """spec -> manifest -> JSON -> manifest -> spec is the identity."""
        manifest = spec.to_manifest()
        wire = json.loads(json.dumps(manifest))  # a real serialization hop
        back = from_manifest(wire)
        assert back == spec
        assert back.to_manifest() == manifest
        assert from_json(spec.to_json()) == spec

    @given(batch_jobs)
    def test_runtime_fields_stay_out_of_manifests(spec):
        """The runtime-only fn slot never rides in (or breaks) a
        manifest."""
        with_fn = dataclasses.replace(spec, fn=lambda ctx: "hi")
        assert with_fn == spec                   # compare=False
        assert "fn" not in with_fn.to_manifest()["spec"]
        assert from_manifest(with_fn.to_manifest()) == spec


def test_round_trip_without_hypothesis():
    """A deterministic round-trip pin so the law is still exercised when
    hypothesis is absent (the property suite above goes deeper)."""
    specs = [
        TrainJob(name="t", steps=7, base_shape=(2, 2), max_data=None,
                 optimizer={"lr": 0.01}, site="gpu", devices=2),
        ServeJob(name="s", gen_lens=(4, 2),
                 requests=[{"id": 0, "prompt": [1, 2]}]),
        ServeJob(name="s2", paged=True, block_size=4, pool_blocks=12,
                 prefix_cache=False, min_replicas=2, max_replicas=4,
                 target_backlog=2.5, ttft_slo_s=0.5),
        BatchJob(name="b", replicas=3, entrypoint="builtins:repr",
                 params={"x": 1}),
        WorkflowRun(name="w", only="train",
                    entrypoint="repro.apps.connect.pipeline:"
                               "add_connect_steps"),
        # tuples nested in free-form dict fields canonicalize to lists
        # at construction, so they too survive the JSON hop unchanged
        WorkflowRun(name="w2", params={"ffn": {"fov": (8, 16, 16)}}),
        TrainJob(name="t2", steps=3, config={"shape": (4, 4)}),
    ]
    for spec in specs:
        wire = json.loads(json.dumps(spec.to_manifest()))
        assert from_manifest(wire) == spec


def manifest(kind="TrainJob", name="t", spec=None, **top):
    m = {"kind": kind, "metadata": {"name": name},
         "spec": {"steps": 5} if spec is None else spec}
    m.update(top)
    return m


@pytest.mark.parametrize("bad,field,hint", [
    (manifest(kind="CronJob"), "kind", "unknown kind"),
    (manifest(kind=None), "kind", "unknown kind"),
    ({"kind": "TrainJob", "metadata": {}}, "metadata.name", "required"),
    (manifest(spec={}), "spec.steps", "required field missing"),
    (manifest(spec={"steps": "ten"}), "spec.steps", "expected an int"),
    (manifest(spec={"steps": True}), "spec.steps", "expected an int"),
    (manifest(spec={"steps": 5, "smoke": "yes"}), "spec.smoke",
     "expected a bool"),
    (manifest(spec={"steps": 5, "base_shape": [1]}), "spec.base_shape",
     "expected 2 items"),
    (manifest(spec={"steps": 5, "warp_drive": 1}), "spec.warp_drive",
     "unknown field"),
    (manifest(spec={"steps": 0}), "spec.steps", ">= 1"),
    (manifest(apiVersion="repro/v2"), "apiVersion", "unsupported version"),
    (manifest(kind="ServeJob", spec={"slots": 0}), "spec.slots", ">= 1"),
    (manifest(kind="ServeJob", spec={"gen_lens": ["a"]}),
     "spec.gen_lens[0]", "expected an int"),
    (manifest(kind="ServeJob", spec={"requests": [{"id": 1}]}),
     "spec.requests[0]", "'id' and 'prompt'"),
    (manifest(kind="BatchJob", spec={"replicas": 0}), "spec.replicas",
     ">= 1"),
    (manifest(kind="BatchJob", spec={"entrypoint": "no-colon"}),
     "spec.entrypoint", "pkg.module:attr"),
    (manifest(kind="WorkflowRun", spec={"resume": 1}), "spec.resume",
     "expected a bool"),
])
def test_malformed_manifests_name_the_field(bad, field, hint):
    with pytest.raises(ManifestError) as e:
        from_manifest(bad)
    assert e.value.field == field, f"expected {field}, got {e.value.field}"
    assert field in str(e.value)        # the message names the field
    assert hint in str(e.value)


def test_direct_construction_validates_too():
    with pytest.raises(ManifestError, match="spec.steps"):
        TrainJob(name="t", steps=0)
    with pytest.raises(ManifestError, match="metadata.name"):
        ServeJob(name="")


def test_entrypoint_resolution():
    assert resolve_entrypoint("builtins:repr") is repr
    with pytest.raises(ManifestError, match="spec.entrypoint"):
        resolve_entrypoint("not.a.module:thing")
    with pytest.raises(ManifestError, match="spec.entrypoint"):
        resolve_entrypoint("builtins:no_such_attr")
    # the declarative twin of the runtime fn slot
    job = BatchJob(name="b", entrypoint="builtins:repr")
    assert job.resolve_fn() is repr
    with pytest.raises(ManifestError, match="spec.entrypoint"):
        BatchJob(name="b").resolve_fn()


def test_from_json_rejects_garbage():
    with pytest.raises(ManifestError, match="not valid JSON"):
        from_json("{nope")
