"""ObjectStore correctness: key-escape containment and path-aware,
subtree-walking ``list`` (the two seed bugs fixed alongside the fabric)."""
import pytest

from repro.data.objectstore import ObjectStore


@pytest.fixture()
def store(tmp_path):
    return ObjectStore(str(tmp_path / "store"))


# ------------------------------------------------------------- key escapes

def test_path_rejects_dotdot_escape(store):
    with pytest.raises(ValueError, match="escapes"):
        store.put("../outside", b"x")


def test_path_rejects_sibling_with_common_prefix(store, tmp_path):
    """The seed's startswith() check admitted /x/store2 under root
    /x/store — Path.relative_to is component-wise and must not."""
    (tmp_path / "store2").mkdir()
    (tmp_path / "store2" / "leak").write_bytes(b"secret")
    with pytest.raises(ValueError, match="escapes"):
        store.get("../store2/leak")
    with pytest.raises(ValueError, match="escapes"):
        store.put("a/../../store2/new", b"x")


def test_path_allows_interior_dotdot(store):
    store.put("a/b/../c", b"x")          # resolves inside the root: fine
    assert store.get("a/c") == b"x"


# ------------------------------------------------------------ list(prefix)

def test_list_prefix_is_path_aware(store):
    store.put("ab/y", b"1")
    store.put("abc/x", b"2")
    assert store.list("ab") == ["ab/y"]           # "abc/x" must NOT match
    assert store.list("ab/") == ["ab/y"]
    assert store.list("abc") == ["abc/x"]
    assert sorted(store.list("")) == ["ab/y", "abc/x"]


def test_list_exact_file_and_missing_prefix(store):
    store.put("w/f/only", b"1")
    assert store.list("w/f/only") == ["w/f/only"]
    assert store.list("w/f/only/") == []          # a file is not a subtree
    assert store.list("nope") == []
    assert store.list("w/nope/") == []


def test_list_walks_only_the_prefix_subtree(store, monkeypatch):
    """Listing one workflow's keys must not rglob the whole store."""
    for i in range(5):
        store.put(f"other{i}/k", b"x")
    store.put("mine/a", b"1")
    store.put("mine/b/c", b"2")
    walked = []
    import pathlib
    orig = pathlib.Path.rglob

    def spy(self, pattern):
        walked.append(str(self))
        return orig(self, pattern)

    monkeypatch.setattr(pathlib.Path, "rglob", spy)
    assert store.list("mine/") == ["mine/a", "mine/b/c"]
    assert walked == [str(store.root / "mine")]   # subtree only, not root


def test_total_bytes_respects_boundary(store):
    store.put("p", b"12345")
    store.put("p2/big", b"x" * 100)
    assert store.total_bytes("p") == 5
